//! Integration: Phase-1 simulator across policies, traces, and the
//! report pipeline (the code paths behind Table I and figures 5–8).

use diagonal_scale::config::ModelConfig;
use diagonal_scale::plane::Configuration;
use diagonal_scale::report::{self, Metric, Surface};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::testkit::TempDir;
use diagonal_scale::workload::TraceBuilder;

fn setup() -> (ModelConfig, Simulator) {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    (cfg, sim)
}

#[test]
fn table_one_reproduces_paper_shape() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let runs = sim.run_paper_set(&trace);
    let (ds, hz, vt) = (&runs[0].summary, &runs[1].summary, &runs[2].summary);

    // Paper Table I: DS 3 viol / lowest latency+objective / cost premium;
    // H-only 32 viol / worst latency+objective; V-only between.
    assert!(ds.violations <= 5, "DiagonalScale violations: {}", ds.violations);
    assert!((25..=40).contains(&hz.violations), "H-only violations: {}", hz.violations);
    assert!(
        ds.violations < vt.violations && vt.violations < hz.violations,
        "violation ordering"
    );
    assert!(ds.avg_latency < vt.avg_latency && vt.avg_latency < hz.avg_latency);
    assert!(ds.avg_objective < vt.avg_objective && vt.avg_objective < hz.avg_objective);
    assert!(ds.avg_cost >= vt.avg_cost && ds.avg_cost >= hz.avg_cost);
    assert!(ds.avg_throughput > hz.avg_throughput);
    // paper: avg required throughput is 9600 synthetic ops
    assert!((ds.avg_required - 9600.0).abs() < 1.0);
}

#[test]
fn diagonal_beats_threshold_strawman() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    let th = sim.run(PolicyKind::Threshold, &trace);
    assert!(ds.summary.violations <= th.summary.violations);
}

#[test]
fn oracle_is_a_lower_bound_on_objective() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    let oracle = sim.run(PolicyKind::Oracle, &trace);
    // oracle ignores rebalance locality, so its objective can't be worse
    // by more than noise
    assert!(oracle.summary.avg_objective <= ds.summary.avg_objective + 1.0);
    assert!(oracle.summary.violations <= ds.summary.violations);
}

#[test]
fn paper_trajectory_visits_both_axes_fig5() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    let hs: std::collections::BTreeSet<usize> =
        ds.records.iter().map(|r| r.config.h_idx).collect();
    let vs: std::collections::BTreeSet<usize> =
        ds.records.iter().map(|r| r.config.v_idx).collect();
    assert!(hs.len() >= 2, "fig 5: H axis must be used");
    assert!(vs.len() >= 2, "fig 5: V axis must be used");
}

#[test]
fn cost_rises_at_peak_and_falls_after_fig7() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    let avg = |r: std::ops::Range<usize>| {
        let n = r.len() as f64;
        ds.records[r].iter().map(|x| x.cost as f64).sum::<f64>() / n
    };
    let low_head = avg(2..10);
    let peak = avg(22..30);
    let low_tail = avg(44..50);
    assert!(peak > low_head, "peak phase must cost more");
    assert!(low_tail < peak, "policy must scale back down after the peak");
}

#[test]
fn sine_trace_tracks_demand() {
    let (cfg, sim) = setup();
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.sine(60.0, 160.0, 20, 100);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    // violations only possible near crests; must be far below half
    assert!(ds.summary.violations < 25, "violations={}", ds.summary.violations);
}

#[test]
fn bursty_trace_is_survivable() {
    let (cfg, sim) = setup();
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.bursty(60.0, 160.0, 0.2, 100, 9);
    let ds = sim.run(PolicyKind::Diagonal, &trace);
    let st = sim.run(PolicyKind::Static, &trace);
    assert!(ds.summary.violations <= st.summary.violations);
}

#[test]
fn plan_queue_extension_makes_the_latency_bound_measured() {
    // §VIII: with the queueing-aware planner, `l_max` bounds *measured*
    // latency (L / (1-u)), not the analytical optimum. The raw Phase-1
    // planner regularly serves steps whose measured latency exceeds its
    // own bound; the queueing-aware planner (with a budget sized for
    // measured latency) does not, except for start/ramp transients.
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);

    let base = Simulator::new(&cfg).run(PolicyKind::Diagonal, &trace);
    let base_over = base
        .records
        .iter()
        .filter(|r| r.latency > cfg.sla.l_max)
        .count();
    assert!(
        base_over > 5,
        "raw planner should regularly exceed its own bound in measured terms: {base_over}"
    );

    let mut qcfg = cfg.clone();
    qcfg.sla.l_max = 10.0; // budget in measured-latency units
    let ext = Simulator::new(&qcfg)
        .with_plan_queue(true)
        .run(PolicyKind::Diagonal, &trace);
    let ext_over = ext
        .records
        .iter()
        .filter(|r| r.latency > qcfg.sla.l_max)
        .count();
    assert!(
        ext_over <= 2,
        "queueing-aware planner must hold its measured bound (transients aside): {ext_over}"
    );
}

#[test]
fn alternate_start_configs_converge() {
    let (cfg, sim0) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let base_tail: Vec<_> = sim0
        .run(PolicyKind::Diagonal, &trace)
        .records
        .iter()
        .skip(40)
        .map(|r| r.config)
        .collect();
    for start in [(0, 0), (3, 3), (0, 3), (3, 0)] {
        let sim = Simulator::new(&cfg).with_start(Configuration::new(start.0, start.1));
        let run = sim.run(PolicyKind::Diagonal, &trace);
        let tail: Vec<_> = run.records.iter().skip(40).map(|r| r.config).collect();
        assert_eq!(tail, base_tail, "start {start:?} must converge to the same regime");
    }
}

#[test]
fn rebalance_weights_affect_movement() {
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);
    let cheap = Simulator::new(&cfg).with_rebalance(0.0, 0.0);
    let expensive = Simulator::new(&cfg).with_rebalance(50.0, 25.0);
    let moves = |run: &diagonal_scale::simulator::RunResult| {
        run.records
            .windows(2)
            .filter(|w| w[0].config != w[1].config)
            .count()
    };
    let free = cheap.run(PolicyKind::Diagonal, &trace);
    let sticky = expensive.run(PolicyKind::Diagonal, &trace);
    assert!(
        moves(&sticky) <= moves(&free),
        "higher rebalance penalty must not increase movement"
    );
}

#[test]
fn figures_pipeline_writes_everything() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let runs = sim.run_paper_set(&trace);
    let model = SurfaceModel::from_config(&cfg);
    let dir = TempDir::new().unwrap();
    let files = report::write_all_figures(dir.path(), &model, &runs, 10000.0).unwrap();
    assert_eq!(files.len(), 10);
    let table = std::fs::read_to_string(dir.path().join("table1.txt")).unwrap();
    assert!(table.contains("DiagonalScale"));
    let fig6 = std::fs::read_to_string(dir.path().join("fig6_latency_over_time.csv")).unwrap();
    assert_eq!(fig6.lines().count(), 51);
}

#[test]
fn heatmap_csvs_reflect_the_model() {
    let (cfg, _) = setup();
    let model = SurfaceModel::from_config(&cfg);
    let csv = report::heatmap_csv(&model, Surface::Cost, 10000.0);
    // fig 1: last row, last column is the most expensive config (8 x
    // xlarge = 8.0 cost units)
    let last = csv.lines().last().unwrap();
    assert!(last.starts_with("8,"));
    assert!(last.ends_with("8.0000"));
}

#[test]
fn timeseries_csv_columns_align_with_policies() {
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let runs = sim.run_paper_set(&trace);
    for metric in [Metric::Latency, Metric::Cost, Metric::Objective, Metric::Throughput] {
        let csv = report::timeseries_csv(&runs, metric);
        let header = csv.lines().next().unwrap();
        assert!(header.contains("DiagonalScale"));
        assert!(header.contains("Horizontal-only"));
        assert!(header.contains("Vertical-only"));
    }
}

#[test]
fn lookahead_with_true_future_nearly_eliminates_ramp_transients() {
    // serve-then-move alignment: the oracle-future lookahead scores
    // level-0 candidates against the demand they will serve, so the
    // paper trace's phase ramps stop producing violations.
    let (cfg, sim) = setup();
    let trace = TraceBuilder::paper(&cfg);
    let greedy = sim.run(PolicyKind::Diagonal, &trace);
    let ahead = sim.run(PolicyKind::Lookahead(3), &trace);
    assert!(greedy.summary.violations >= 2, "ramps trip the reactive policy");
    assert!(
        ahead.summary.violations <= 1,
        "lookahead must pre-scale through the ramps: {}",
        ahead.summary.violations
    );
}

#[test]
fn seasonal_forecast_earns_most_of_the_oracle_benefit() {
    use diagonal_scale::config::MoveFlags;
    use diagonal_scale::forecast::SeasonalNaive;
    use diagonal_scale::policy::ForecastLookahead;
    use diagonal_scale::workload::Trace;

    let (cfg, sim) = setup();
    let one = TraceBuilder::paper(&cfg);
    let mut points = one.points.clone();
    points.extend(one.points.iter().copied());
    points.extend(one.points.iter().copied());
    let cycle = Trace { name: "paper-x3".into(), points };

    let reactive = sim.run(PolicyKind::Diagonal, &cycle);
    let mut fl = ForecastLookahead::new(
        MoveFlags::DIAGONAL,
        3,
        SeasonalNaive::new(50),
        cfg.write_ratio(),
    );
    let seasonal = sim.run_boxed(&mut fl, "fl-seasonal", &cycle);
    assert!(
        seasonal.summary.violations < reactive.summary.violations,
        "seasonal {} vs reactive {}",
        seasonal.summary.violations,
        reactive.summary.violations
    );
}

#[test]
fn lookahead_reduces_spike_violations() {
    let (cfg, sim) = setup();
    let b = TraceBuilder::from_config(&cfg);
    // sudden 60 -> 160 spike: one-step local search needs several steps
    // (paper §VII limitation); lookahead (§VIII) pre-scales.
    let trace = b.spike(60.0, 160.0, 15, 10, 40);
    let greedy = sim.run(PolicyKind::Diagonal, &trace);
    let ahead = sim.run(PolicyKind::Lookahead(3), &trace);
    assert!(ahead.summary.violations <= greedy.summary.violations);
}
