//! Integration: the Phase-2 DES cluster driven by the coordinator —
//! the "empirical calibration" path the paper defers to future work
//! (§VIII), exercised end to end: observe → plan → actuate → measure,
//! plus online calibration from measured data.

use diagonal_scale::calibrate::{Calibrator, Observation};
use diagonal_scale::cluster::{ClusterParams, ClusterSim};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::coordinator::{self, native_coordinator, Backend, Coordinator};
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::{DiagonalScale, StaticPolicy, Threshold};
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn cfg() -> ModelConfig {
    ModelConfig::default_paper()
}

#[test]
fn coordinator_beats_static_on_measured_violations() {
    let cfg = cfg();
    let trace = TraceBuilder::paper(&cfg);
    let mut diag = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        7,
    );
    let mut stat = native_coordinator(
        &cfg,
        Box::new(StaticPolicy),
        ClusterParams::default(),
        7,
    );
    let d = coordinator::summarize(&diag.run_trace(&trace).unwrap());
    let s = coordinator::summarize(&stat.run_trace(&trace).unwrap());
    assert!(
        d.violations < s.violations,
        "diag {} vs static {}",
        d.violations,
        s.violations
    );
    assert!(d.completed_ratio > s.completed_ratio);
}

#[test]
fn coordinator_beats_threshold_on_completion() {
    let cfg = cfg();
    let trace = TraceBuilder::paper(&cfg);
    let mut diag = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        11,
    );
    let mut thr = native_coordinator(
        &cfg,
        Box::new(Threshold::default()),
        ClusterParams::default(),
        11,
    );
    let d = coordinator::summarize(&diag.run_trace(&trace).unwrap());
    let t = coordinator::summarize(&thr.run_trace(&trace).unwrap());
    assert!(d.completed_ratio >= t.completed_ratio - 0.02);
    assert!(d.violations <= t.violations + 2);
}

#[test]
fn conservation_holds_across_a_full_run() {
    let cfg = cfg();
    let trace = TraceBuilder::paper(&cfg);
    let mut c = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        13,
    );
    c.run_trace(&trace).unwrap();
    let cl = c.cluster();
    let total = cl.total_completed + cl.total_dropped;
    assert!(
        (cl.total_offered - total).abs() < 1e-6 * cl.total_offered,
        "ops must be conserved: offered={} completed+dropped={}",
        cl.total_offered,
        total
    );
}

#[test]
fn rebalances_happen_but_are_bounded() {
    let cfg = cfg();
    let trace = TraceBuilder::paper(&cfg);
    let mut c = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        17,
    );
    let reports = c.run_trace(&trace).unwrap();
    let s = coordinator::summarize(&reports);
    assert!(s.reconfigurations >= 2, "must adapt to the phases");
    assert!(
        s.reconfigurations <= 20,
        "rebalance penalty must prevent thrash: {}",
        s.reconfigurations
    );
    assert!(s.total_moved_shards > 0, "H changes move shards");
}

#[test]
fn hlo_backend_drives_the_cluster() {
    // the PJRT path on the decision loop: neighbor scoring through the
    // AOT-compiled Pallas kernel
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return;
    }
    let cfg = cfg();
    let engine = diagonal_scale::runtime::SurfaceEngine::new(
        diagonal_scale::runtime::Engine::load(&artifacts).unwrap(),
        &cfg,
    )
    .unwrap();
    let cluster = ClusterSim::new(&cfg, ClusterParams::default(), 19);
    let mut coord = Coordinator::new(
        &cfg,
        cluster,
        Backend::Hlo { engine, moves: diagonal_scale::config::MoveFlags::DIAGONAL },
    );
    let trace = TraceBuilder::paper(&cfg);
    let reports = coord.run_trace(&trace).unwrap();
    let s = coordinator::summarize(&reports);
    assert_eq!(s.steps, 50);
    assert!(s.reconfigurations >= 2);
    assert!(s.completed_ratio > 0.9, "completed={}", s.completed_ratio);
}

#[test]
fn hlo_and_native_backends_agree_on_decisions() {
    let artifacts = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifacts.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
        return;
    }
    let cfg = cfg();
    let engine = diagonal_scale::runtime::SurfaceEngine::new(
        diagonal_scale::runtime::Engine::load(&artifacts).unwrap(),
        &cfg,
    )
    .unwrap();
    // identical seeds => identical measured metrics => identical plans
    let mut native = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        23,
    );
    let mut hlo = Coordinator::new(
        &cfg,
        ClusterSim::new(&cfg, ClusterParams::default(), 23),
        Backend::Hlo { engine, moves: diagonal_scale::config::MoveFlags::DIAGONAL },
    );
    let trace = TraceBuilder::paper(&cfg);
    let a = native.run_trace(&trace).unwrap();
    let b = hlo.run_trace(&trace).unwrap();
    let ca: Vec<_> = a.iter().map(|r| r.served_config).collect();
    let cb: Vec<_> = b.iter().map(|r| r.served_config).collect();
    assert_eq!(ca, cb, "native and PJRT planners must make the same moves");
}

#[test]
fn online_calibration_from_cluster_measurements() {
    // paper §VIII: benchmark selected plane points on the "real" system
    // and fit the surfaces from measurements.
    let cfg = cfg();
    let plane = cfg.plane();
    let mut cal = Calibrator::new(cfg.surfaces);
    for c in plane.iter() {
        let mut cluster = ClusterSim::new(&cfg, ClusterParams::default(), 29);
        cluster.apply(c);
        // settle after the reconfiguration window
        for _ in 0..3 {
            cluster.step(WorkloadPoint::new(100.0, 0.3));
        }
        // probe at moderate utilization for latency
        let probe = cluster.capacity() as f32 * 0.3;
        let m = cluster.step(WorkloadPoint::new(probe, 0.3));
        cal.observe(
            &plane,
            Observation {
                config: c,
                latency: m.avg_latency,
                throughput: cluster.capacity(),
            },
        );
    }
    let lat = cal.fit_latency().expect("latency fit");
    let thr = cal.fit_throughput().expect("throughput fit");
    assert!(lat.rmse.is_finite());
    // measured capacity ~ kappa * min_resource * H (no phi in the DES),
    // so the fitted kappa must land near the configured one and the
    // fitted omega near zero.
    assert!(
        (thr.kappa - cfg.surfaces.kappa as f64).abs() / (cfg.surfaces.kappa as f64) < 0.1,
        "kappa={}",
        thr.kappa
    );
    assert!(thr.omega.abs() < 0.1, "omega={}", thr.omega);
    let calibrated = cal.calibrated_config();
    assert!(calibrated.kappa > 0.0);
}

#[test]
fn ewma_smoothing_is_configurable() {
    let cfg = cfg();
    let mut c = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        31,
    );
    c.ewma_alpha = 1.0; // no smoothing: estimate == last observation
    c.tick(0, WorkloadPoint::new(5000.0, 0.3)).unwrap();
    let r = c.tick(1, WorkloadPoint::new(9000.0, 0.3)).unwrap();
    assert!((r.demand_estimate - 9000.0).abs() < 1.0);
}

#[test]
fn cluster_start_config_matches_model_config() {
    let cfg = cfg();
    let cluster = ClusterSim::new(&cfg, ClusterParams::default(), 1);
    assert_eq!(
        cluster.current(),
        Configuration::new(cfg.policy.start[0], cfg.policy.start[1])
    );
}
