//! Property tests for the fleet invariants: the budget arbiter never
//! exceeds the global budget (with or without class envelopes and
//! burst credits), its admission order is total (priority classes
//! break ties, input order is irrelevant — including which *candidate*
//! each tenant degrades to and which sheds are actuated), rescue
//! preemption still beats economic moves under the planning admission,
//! and the fairness guard bounds consecutive denials of SLA-violating
//! tenants whenever their rescue is affordable.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    BudgetArbiter, Candidate, ClassEnvelopes, FleetSimulator, PriorityClass, Proposal, TenantSpec,
    Verdict,
};
use diagonal_scale::plane::Configuration;
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::{TraceBuilder, XorShift64};

fn rand_class(rng: &mut XorShift64) -> PriorityClass {
    match rng.below(3) {
        0 => PriorityClass::Gold,
        1 => PriorityClass::Silver,
        _ => PriorityClass::Bronze,
    }
}

fn rand_config(rng: &mut XorShift64) -> Configuration {
    Configuration::new(rng.below(4) as usize, rng.below(4) as usize)
}

/// A random proposal with self-consistent shape: a hold (no
/// candidates, possibly shed offers) or a ranked candidate list whose
/// alternatives are strictly cheaper than the best move.
fn rand_proposal(rng: &mut XorShift64, tenant: usize) -> Proposal {
    let from = rand_config(rng);
    let cost_from = uniform(rng, 0.08, 8.0);
    let hold = rng.next_f64() < 0.25;
    let mut candidates = Vec::new();
    if !hold {
        let n_cands = 1 + rng.below(3) as usize;
        let mut cost = uniform(rng, 0.08, 8.0);
        for _ in 0..n_cands {
            candidates.push(Candidate::priced(rand_config(rng), cost, uniform(rng, 0.0, 50.0)));
            // alternatives get strictly cheaper down the list
            cost *= uniform(rng, 0.3, 0.95);
        }
    }
    let sla_violating = rng.next_f64() < 0.3;
    let emergency = !hold && rng.next_f64() < 0.1;
    let mut sheds = Vec::new();
    if hold && !sla_violating && rng.next_f64() < 0.6 {
        sheds.push(Candidate::priced(
            rand_config(rng),
            cost_from * uniform(rng, 0.3, 0.95),
            uniform(rng, 0.0, 5.0),
        ));
    }
    Proposal {
        tenant,
        class: rand_class(rng),
        from,
        cost_from,
        current_score: 0.0,
        emergency,
        sla_violating,
        denial_streak: rng.below(6) as usize,
        fallback: false,
        candidates,
        sheds,
    }
}

fn rand_proposals(rng: &mut XorShift64, n: usize) -> Vec<Proposal> {
    (0..n).map(|i| rand_proposal(rng, i)).collect()
}

fn rand_envelopes(rng: &mut XorShift64) -> ClassEnvelopes {
    ClassEnvelopes::new(
        uniform(rng, 0.1, 1.0),
        uniform(rng, 0.1, 1.0),
        uniform(rng, 0.1, 1.0),
    )
}

/// Recompute projected spend from the admitted options.
fn recompute_spend(proposals: &[Proposal], adm: &diagonal_scale::fleet::Admission) -> f32 {
    let base: f32 = proposals.iter().map(|p| p.cost_from).sum();
    base + proposals
        .iter()
        .zip(adm.verdicts.iter().zip(&adm.chosen))
        .map(|(p, (v, c))| match v {
            Verdict::Hold | Verdict::DeniedBudget | Verdict::DeniedRescueUnaffordable => 0.0,
            Verdict::AdmittedShed => p.sheds[c.unwrap()].cost_to - p.cost_from,
            _ => p.candidates[c.unwrap()].cost_to - p.cost_from,
        })
        .sum::<f32>()
}

#[test]
fn arbiter_never_exceeds_budget() {
    forall(300, 0xF1EE7, |_, rng| {
        let n = 1 + rng.below(24) as usize;
        let proposals = rand_proposals(rng, n);
        let base: f32 = proposals.iter().map(|p| p.cost_from).sum();
        // budget at/above the base spend: admissions must keep it
        let budget = base * uniform(rng, 1.0, 1.6) + 0.01;
        for arb in [
            BudgetArbiter::new(budget, 3),
            BudgetArbiter::flat(budget, 3),
            BudgetArbiter::new(budget, 3).with_envelopes(rand_envelopes(rng)),
        ] {
            let adm = arb.admit(&proposals);
            assert!(
                adm.projected_spend <= budget + 1e-3,
                "projected {} over budget {budget}",
                adm.projected_spend
            );
            // projected spend must equal base + admitted deltas
            let recomputed = recompute_spend(&proposals, &adm);
            assert!(
                (recomputed - adm.projected_spend).abs() <= 1e-3,
                "recomputed {recomputed} vs projected {}",
                adm.projected_spend
            );
        }
    });
}

#[test]
fn shrinks_and_holds_are_always_admitted() {
    forall(200, 0xCAFE, |_, rng| {
        let proposals = rand_proposals(rng, 1 + rng.below(16) as usize);
        let budget: f32 = proposals.iter().map(|p| p.cost_from).sum::<f32>() + 0.01;
        let adm = BudgetArbiter::new(budget, 3).admit(&proposals);
        for (p, v) in proposals.iter().zip(&adm.verdicts) {
            if !p.is_move() {
                assert!(
                    matches!(v, Verdict::Hold | Verdict::AdmittedShed),
                    "hold got {v:?}"
                );
            } else if p.cost_delta() <= 0.0 {
                assert_eq!(*v, Verdict::AdmittedShrink);
            }
        }
    });
}

#[test]
fn admission_is_independent_of_input_order() {
    forall(200, 0x0BDE2, |_, rng| {
        let n = 2 + rng.below(16) as usize;
        let mut proposals = rand_proposals(rng, n);
        let budget: f32 =
            proposals.iter().map(|p| p.cost_from).sum::<f32>() * uniform(rng, 1.0, 1.4) + 0.01;
        for arb in [
            BudgetArbiter::new(budget, 3),
            BudgetArbiter::new(budget, 3).with_envelopes(rand_envelopes(rng)),
        ] {
            // per-tenant outcome: (verdict, chosen option), keyed by id
            let outcome = |ps: &[Proposal]| -> Vec<(usize, Verdict, Option<usize>)> {
                let adm = arb.admit(ps);
                let mut out: Vec<(usize, Verdict, Option<usize>)> = ps
                    .iter()
                    .zip(adm.verdicts.iter().zip(&adm.chosen))
                    .map(|(p, (v, c))| (p.tenant, *v, *c))
                    .collect();
                out.sort_by_key(|&(t, _, _)| t);
                out
            };
            let a = outcome(&proposals);
            // Fisher–Yates shuffle, then re-admit
            for i in (1..proposals.len()).rev() {
                let j = rng.below(i as u64 + 1) as usize;
                proposals.swap(i, j);
            }
            let b = outcome(&proposals);
            assert_eq!(a, b, "admission depended on input order");
        }
    });
}

#[test]
fn priority_class_breaks_ties_for_the_last_slot() {
    forall(100, 0xC1A55, |_, rng| {
        // two otherwise-identical cost-increasing proposals; budget fits
        // exactly one: the higher class must win regardless of position
        let cost_from = uniform(rng, 0.1, 2.0);
        let delta = uniform(rng, 0.2, 2.0);
        let mut lo = rand_proposal(rng, 0);
        lo.class = PriorityClass::Bronze;
        lo.from = Configuration::new(0, 0);
        lo.cost_from = cost_from;
        lo.candidates =
            vec![Candidate::priced(Configuration::new(1, 1), cost_from + delta, 10.0)];
        lo.emergency = false;
        lo.sla_violating = false;
        lo.denial_streak = 0;
        lo.sheds.clear();
        let mut hi = lo.clone();
        hi.tenant = 1;
        hi.class = if rng.next_f64() < 0.5 { PriorityClass::Gold } else { PriorityClass::Silver };

        // one increase fits, not two — replicate the arbiter's f32
        // arithmetic exactly (base + cost_delta) so the boundary admits
        let budget = (cost_from + cost_from) + lo.cost_delta();
        let arb = BudgetArbiter::new(budget, 3);
        let first_hi = rng.next_f64() < 0.5;
        let proposals =
            if first_hi { vec![hi.clone(), lo.clone()] } else { vec![lo, hi] };
        let adm = arb.admit(&proposals);
        for (p, v) in proposals.iter().zip(&adm.verdicts) {
            if p.tenant == 1 {
                assert!(v.admitted(), "higher class lost the tie");
            } else {
                assert!(v.denied(), "lower class won the tie");
            }
        }
    });
}

#[test]
fn rescue_preemption_beats_economic_moves() {
    forall(100, 0x0E5C0E, |_, rng| {
        // a starved violating Bronze rescue and a Gold economic move
        // compete for headroom that fits only one: the rescue wins
        // under both the flat and the planning admission
        let cost_from = uniform(rng, 0.2, 1.0);
        let delta = uniform(rng, 0.3, 1.5);
        let mut bronze = rand_proposal(rng, 0);
        bronze.class = PriorityClass::Bronze;
        bronze.cost_from = cost_from;
        bronze.candidates =
            vec![Candidate::priced(Configuration::new(1, 1), cost_from + delta, 1.0)];
        bronze.emergency = false;
        bronze.sla_violating = true;
        bronze.denial_streak = 3;
        bronze.sheds.clear();
        let mut gold = bronze.clone();
        gold.tenant = 1;
        gold.class = PriorityClass::Gold;
        gold.sla_violating = false;
        gold.denial_streak = 0;
        gold.candidates[0].gain = 100.0;
        let budget = (cost_from + cost_from) + delta;
        for arb in [BudgetArbiter::new(budget, 3), BudgetArbiter::flat(budget, 3)] {
            let adm = arb.admit(&[gold.clone(), bronze.clone()]);
            assert_eq!(adm.verdicts[1], Verdict::AdmittedRescue, "rescue lost to economics");
            assert!(adm.verdicts[0].denied());
        }
    });
}

#[test]
fn degradation_walks_to_the_best_fitting_candidate() {
    forall(200, 0xDE62ADE, |_, rng| {
        let mut p = rand_proposal(rng, 0);
        while p.candidates.len() < 2 {
            p = rand_proposal(rng, 0);
        }
        p.sheds.clear();
        p.denial_streak = 0; // keep the rescue pass out of this walk
        let budget = p.cost_from.max(p.candidates.last().unwrap().cost_to) + 0.01;
        let adm = BudgetArbiter::new(budget, 3).admit(&[p.clone()]);
        let v = adm.verdicts[0];
        if let Some(ci) = adm.chosen[0] {
            // every earlier-ranked candidate must NOT have fit (the
            // arbiter rejects at budget + FIT_EPS = 1e-4, so anything
            // walked past costs strictly more than the budget)
            for c in p.candidates.iter().take(ci) {
                assert!(c.cost_to > budget, "walk skipped a fitting candidate");
            }
            assert!(p.candidates[ci].cost_to <= budget + 1e-3);
            if ci > 0 {
                assert_eq!(v, Verdict::AdmittedDegraded);
            }
        } else {
            assert!(v.denied() || v == Verdict::Hold);
        }
        // the flat arbiter never degrades
        let adm = BudgetArbiter::flat(budget, 3).admit(&[p]);
        assert_ne!(adm.verdicts[0], Verdict::AdmittedDegraded);
    });
}

#[test]
fn fleet_spend_never_exceeds_budget_over_a_full_run() {
    let cfg = ModelConfig::default_paper();
    forall(12, 0xB0D9E7, |case, rng| {
        let n = 2 + rng.below(8) as usize;
        let base = TraceBuilder::paper(&cfg);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{case}-{i}"),
                    rand_class(rng),
                    base.shifted(rng.below(50) as usize),
                )
            })
            .collect();
        // start spend is n * cost(H=2, medium) = n * 0.4; budgets from
        // barely-above-start to comfortable
        let budget = n as f32 * uniform(rng, 0.5, 3.0);
        let arb = if rng.next_f64() < 0.5 {
            BudgetArbiter::new(budget, 3).with_envelopes(rand_envelopes(rng))
        } else {
            BudgetArbiter::new(budget, 3)
        };
        let mut fleet = FleetSimulator::with_arbiter(&cfg, specs, arb);
        let res = fleet.run(75);
        assert!(
            res.within_budget(budget),
            "case {case}: peak {} over budget {budget}",
            res.peak_spend()
        );
        // serve-then-move consistency: projection == next tick's spend
        for w in res.ticks.windows(2) {
            assert!((w[0].projected_spend - w[1].spend).abs() < 1e-3);
        }
    });
}

#[test]
fn fairness_guard_bounds_consecutive_denials() {
    let cfg = ModelConfig::default_paper();
    const K: usize = 3;
    forall(10, 0xFA12, |case, rng| {
        let n = 4 + rng.below(6) as usize;
        let base = TraceBuilder::paper(&cfg);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{case}-{i}"),
                    rand_class(rng),
                    base.shifted(rng.below(50) as usize),
                )
            })
            .collect();
        // tight enough to force denials, loose enough that a single
        // move always fits alongside the fleet's serving configs
        let budget = n as f32 * uniform(rng, 1.2, 1.8);
        let mut fleet = FleetSimulator::new(&cfg, specs, budget, K);
        fleet.run(100);
        for t in fleet.tenants() {
            // the guard puts starved SLA-violating tenants ahead of all
            // economic moves; only unaffordable rescues (budget already
            // consumed by cost cuts / more-starved rescues) may push a
            // streak past K
            if t.rescue_unaffordable_total == 0 {
                assert!(
                    t.max_denial_streak <= K,
                    "case {case}: tenant {} starved for {} ticks (K={K})",
                    t.name(),
                    t.max_denial_streak
                );
            }
        }
    });
}

#[test]
fn holding_but_violating_tenant_cannot_starve_forever() {
    use diagonal_scale::cluster::{ClusterParams, EventSim};
    // a tenant whose substrate measures persistent SLA violations the
    // analytical planner cannot see (an artificially tight measured
    // bound) must escalate out of its start config instead of
    // holding-and-violating silently forever
    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::from_config(&cfg);
    let specs = vec![TenantSpec {
        start: Configuration::new(0, 3),
        ..TenantSpec::from_config(
            &cfg,
            "tight",
            PriorityClass::Bronze,
            base.constant(60.0, 50),
        )
    }];
    let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
    // every measured p99 violates the artificially tight bound
    let params = ClusterParams { sla_latency: 1e-9, ..ClusterParams::default() };
    fleet.tenants_mut()[0].attach_substrate(Box::new(EventSim::new(&cfg, params, 7)));
    let start = fleet.tenants()[0].current();
    fleet.run(20);
    assert_ne!(
        fleet.tenants()[0].current(),
        start,
        "holding-but-violating tenant never escalated"
    );
}

#[test]
fn contention_prefers_higher_classes_end_to_end() {
    // one Gold and one Bronze tenant with identical demand under a
    // budget that cannot scale both: Gold must see no more denials than
    // Bronze, and collect at least as much capacity (total throughput).
    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::paper(&cfg);
    let specs = vec![
        TenantSpec::from_config(&cfg, "gold", PriorityClass::Gold, base.clone()),
        TenantSpec::from_config(&cfg, "bronze", PriorityClass::Bronze, base.clone()),
    ];
    // the peak-feasible config (H=4, xlarge) costs 4.0/h; a 6.0 budget
    // lets exactly one tenant take it while the other holds at 1.8/h
    let mut fleet = FleetSimulator::new(&cfg, specs, 6.0, 3);
    let res = fleet.run(50);
    assert!(res.within_budget(6.0));
    let gold = &res.report.tenants[0];
    let bronze = &res.report.tenants[1];
    assert!(res.report.denied_moves > 0, "budget never bit");
    assert!(
        gold.denied < bronze.denied,
        "gold denied {} vs bronze {}",
        gold.denied,
        bronze.denied
    );
    assert!(gold.summary.avg_throughput > bronze.summary.avg_throughput);
}
