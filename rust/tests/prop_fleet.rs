//! Property tests for the fleet invariants (satellites of the fleet
//! subsystem): the budget arbiter never exceeds the global budget, its
//! admission order is total (priority classes break ties, input order
//! is irrelevant), and the fairness guard bounds consecutive denials of
//! SLA-violating tenants whenever their rescue is affordable.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    BudgetArbiter, FleetSimulator, PriorityClass, Proposal, TenantSpec, Verdict,
};
use diagonal_scale::plane::Configuration;
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::{TraceBuilder, XorShift64};

fn rand_class(rng: &mut XorShift64) -> PriorityClass {
    match rng.below(3) {
        0 => PriorityClass::Gold,
        1 => PriorityClass::Silver,
        _ => PriorityClass::Bronze,
    }
}

/// A random proposal with self-consistent shape (hold ⇔ equal costs).
fn rand_proposal(rng: &mut XorShift64, tenant: usize) -> Proposal {
    let from = Configuration::new(rng.below(4) as usize, rng.below(4) as usize);
    let hold = rng.next_f64() < 0.2;
    let to = if hold {
        from
    } else {
        Configuration::new(rng.below(4) as usize, rng.below(4) as usize)
    };
    let cost_from = uniform(rng, 0.08, 8.0);
    let cost_to = if to == from { cost_from } else { uniform(rng, 0.08, 8.0) };
    Proposal {
        tenant,
        class: rand_class(rng),
        from,
        to,
        cost_from,
        cost_to,
        gain: uniform(rng, -2.0, 50.0),
        emergency: rng.next_f64() < 0.1,
        sla_violating: rng.next_f64() < 0.3,
        denial_streak: rng.below(6) as usize,
    }
}

fn rand_proposals(rng: &mut XorShift64, n: usize) -> Vec<Proposal> {
    (0..n).map(|i| rand_proposal(rng, i)).collect()
}

#[test]
fn arbiter_never_exceeds_budget() {
    forall(300, 0xF1EE7, |_, rng| {
        let n = 1 + rng.below(24) as usize;
        let proposals = rand_proposals(rng, n);
        let base: f32 = proposals.iter().map(|p| p.cost_from).sum();
        // budget at/above the base spend: admissions must keep it
        let budget = base * uniform(rng, 1.0, 1.6) + 0.01;
        let adm = BudgetArbiter::new(budget, 3).admit(&proposals);
        assert!(
            adm.projected_spend <= budget + 1e-3,
            "projected {} over budget {budget}",
            adm.projected_spend
        );
        // projected spend must equal base + admitted deltas
        let recomputed: f32 = base
            + proposals
                .iter()
                .zip(&adm.verdicts)
                .filter(|(p, v)| v.admitted() && p.is_move())
                .map(|(p, _)| p.cost_delta())
                .sum::<f32>();
        assert!(
            (recomputed - adm.projected_spend).abs() <= 1e-3,
            "recomputed {recomputed} vs projected {}",
            adm.projected_spend
        );
    });
}

#[test]
fn shrinks_and_holds_are_always_admitted() {
    forall(200, 0xCAFE, |_, rng| {
        let proposals = rand_proposals(rng, 1 + rng.below(16) as usize);
        let budget: f32 = proposals.iter().map(|p| p.cost_from).sum::<f32>() + 0.01;
        let adm = BudgetArbiter::new(budget, 3).admit(&proposals);
        for (p, v) in proposals.iter().zip(&adm.verdicts) {
            if !p.is_move() {
                assert_eq!(*v, Verdict::Hold);
            } else if p.cost_delta() <= 0.0 {
                assert_eq!(*v, Verdict::AdmittedShrink);
            }
        }
    });
}

#[test]
fn admission_is_independent_of_input_order() {
    forall(200, 0x0BDE2, |_, rng| {
        let n = 2 + rng.below(16) as usize;
        let mut proposals = rand_proposals(rng, n);
        let budget: f32 =
            proposals.iter().map(|p| p.cost_from).sum::<f32>() * uniform(rng, 1.0, 1.4) + 0.01;
        let arb = BudgetArbiter::new(budget, 3);

        let adm_a = arb.admit(&proposals);
        let mut admitted_a: Vec<usize> = proposals
            .iter()
            .zip(&adm_a.verdicts)
            .filter(|(_, v)| v.admitted())
            .map(|(p, _)| p.tenant)
            .collect();

        // Fisher–Yates shuffle, then re-admit
        for i in (1..proposals.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            proposals.swap(i, j);
        }
        let adm_b = arb.admit(&proposals);
        let mut admitted_b: Vec<usize> = proposals
            .iter()
            .zip(&adm_b.verdicts)
            .filter(|(_, v)| v.admitted())
            .map(|(p, _)| p.tenant)
            .collect();

        admitted_a.sort_unstable();
        admitted_b.sort_unstable();
        assert_eq!(admitted_a, admitted_b, "admission depended on input order");
        assert!((adm_a.projected_spend - adm_b.projected_spend).abs() < 1e-3);
    });
}

#[test]
fn priority_class_breaks_ties_for_the_last_slot() {
    forall(100, 0xC1A55, |_, rng| {
        // two otherwise-identical cost-increasing proposals; budget fits
        // exactly one: the higher class must win regardless of position
        let cost_from = uniform(rng, 0.1, 2.0);
        let delta = uniform(rng, 0.2, 2.0);
        let mut lo = rand_proposal(rng, 0);
        lo.class = PriorityClass::Bronze;
        lo.from = Configuration::new(0, 0);
        lo.to = Configuration::new(1, 1);
        lo.cost_from = cost_from;
        lo.cost_to = cost_from + delta;
        lo.gain = 10.0;
        lo.emergency = false;
        lo.sla_violating = false;
        lo.denial_streak = 0;
        let mut hi = lo;
        hi.tenant = 1;
        hi.class = if rng.next_f64() < 0.5 { PriorityClass::Gold } else { PriorityClass::Silver };

        // one increase fits, not two — replicate the arbiter's f32
        // arithmetic exactly (base + cost_delta) so the boundary admits
        let budget = (cost_from + cost_from) + lo.cost_delta();
        let arb = BudgetArbiter::new(budget, 3);
        let first_hi = rng.next_f64() < 0.5;
        let proposals = if first_hi { vec![hi, lo] } else { vec![lo, hi] };
        let adm = arb.admit(&proposals);
        for (p, v) in proposals.iter().zip(&adm.verdicts) {
            if p.tenant == 1 {
                assert!(v.admitted(), "higher class lost the tie");
            } else {
                assert!(v.denied(), "lower class won the tie");
            }
        }
    });
}

#[test]
fn fleet_spend_never_exceeds_budget_over_a_full_run() {
    let cfg = ModelConfig::default_paper();
    forall(12, 0xB0D9E7, |case, rng| {
        let n = 2 + rng.below(8) as usize;
        let base = TraceBuilder::paper(&cfg);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{case}-{i}"),
                    rand_class(rng),
                    base.shifted(rng.below(50) as usize),
                )
            })
            .collect();
        // start spend is n * cost(H=2, medium) = n * 0.4; budgets from
        // barely-above-start to comfortable
        let budget = n as f32 * uniform(rng, 0.5, 3.0);
        let mut fleet = FleetSimulator::new(&cfg, specs, budget, 3);
        let res = fleet.run(75);
        assert!(
            res.within_budget(budget),
            "case {case}: peak {} over budget {budget}",
            res.peak_spend()
        );
        // serve-then-move consistency: projection == next tick's spend
        for w in res.ticks.windows(2) {
            assert!((w[0].projected_spend - w[1].spend).abs() < 1e-3);
        }
    });
}

#[test]
fn fairness_guard_bounds_consecutive_denials() {
    let cfg = ModelConfig::default_paper();
    const K: usize = 3;
    forall(10, 0xFA12, |case, rng| {
        let n = 4 + rng.below(6) as usize;
        let base = TraceBuilder::paper(&cfg);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{case}-{i}"),
                    rand_class(rng),
                    base.shifted(rng.below(50) as usize),
                )
            })
            .collect();
        // tight enough to force denials, loose enough that a single
        // move always fits alongside the fleet's serving configs
        let budget = n as f32 * uniform(rng, 1.2, 1.8);
        let mut fleet = FleetSimulator::new(&cfg, specs, budget, K);
        fleet.run(100);
        for t in fleet.tenants() {
            // the guard puts starved SLA-violating tenants ahead of all
            // economic moves; only unaffordable rescues (budget already
            // consumed by cost cuts / more-starved rescues) may push a
            // streak past K
            if t.rescue_unaffordable_total == 0 {
                assert!(
                    t.max_denial_streak <= K,
                    "case {case}: tenant {} starved for {} ticks (K={K})",
                    t.name(),
                    t.max_denial_streak
                );
            }
        }
    });
}

#[test]
fn contention_prefers_higher_classes_end_to_end() {
    // one Gold and one Bronze tenant with identical demand under a
    // budget that cannot scale both: Gold must see no more denials than
    // Bronze, and collect at least as much capacity (total throughput).
    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::paper(&cfg);
    let specs = vec![
        TenantSpec::from_config(&cfg, "gold", PriorityClass::Gold, base.clone()),
        TenantSpec::from_config(&cfg, "bronze", PriorityClass::Bronze, base.clone()),
    ];
    // the peak-feasible config (H=4, xlarge) costs 4.0/h; a 6.0 budget
    // lets exactly one tenant take it while the other holds at 1.8/h
    let mut fleet = FleetSimulator::new(&cfg, specs, 6.0, 3);
    let res = fleet.run(50);
    assert!(res.within_budget(6.0));
    let gold = &res.report.tenants[0];
    let bronze = &res.report.tenants[1];
    assert!(res.report.denied_moves > 0, "budget never bit");
    assert!(
        gold.denied < bronze.denied,
        "gold denied {} vs bronze {}",
        gold.denied,
        bronze.denied
    );
    assert!(gold.summary.avg_throughput > bronze.summary.avg_throughput);
}
