//! Failure injection: corrupt/missing artifacts, impossible workloads,
//! and node failures mid-run — the system must fail loudly where it
//! should and degrade gracefully where it can.

use diagonal_scale::cluster::{ClusterParams, ClusterSim};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::coordinator::{self, native_coordinator};
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::DiagonalScale;
use diagonal_scale::runtime::Engine;
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::testkit::TempDir;
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Tests that tamper with *real* artifacts skip when `make artifacts`
/// has not run (the corruption-handling paths they exercise need a
/// valid manifest to start from).
macro_rules! require_artifacts {
    () => {
        if !artifacts_dir().join("manifest.json").exists() {
            eprintln!("skipping: artifacts missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn missing_artifact_dir_is_a_clear_error() {
    let err = Engine::load("/definitely/not/a/real/dir").map(|_| ()).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "got: {msg}");
}

#[test]
fn corrupt_manifest_is_rejected() {
    let dir = TempDir::new().unwrap();
    std::fs::write(dir.path().join("manifest.json"), "{ not json !").unwrap();
    assert!(Engine::load(dir.path()).is_err());
}

#[test]
fn manifest_with_wrong_abi_is_rejected() {
    require_artifacts!();
    let dir = TempDir::new().unwrap();
    let real = std::fs::read_to_string(artifacts_dir().join("manifest.json")).unwrap();
    let tampered = real.replace("\"abi_version\": 1", "\"abi_version\": 99");
    std::fs::write(dir.path().join("manifest.json"), tampered).unwrap();
    let err = Engine::load(dir.path()).map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains("ABI"));
}

#[test]
fn manifest_referencing_missing_hlo_is_rejected() {
    require_artifacts!();
    let dir = TempDir::new().unwrap();
    let real = std::fs::read_to_string(artifacts_dir().join("manifest.json")).unwrap();
    std::fs::write(dir.path().join("manifest.json"), real).unwrap();
    // no .hlo.txt files copied
    let err = Engine::load(dir.path()).map(|_| ()).unwrap_err();
    assert!(format!("{err:#}").contains("not found"));
}

#[test]
fn corrupt_hlo_text_is_rejected() {
    require_artifacts!();
    let dir = TempDir::new().unwrap();
    for entry in std::fs::read_dir(artifacts_dir()).unwrap() {
        let p = entry.unwrap().path();
        let name = p.file_name().unwrap().to_string_lossy().to_string();
        if name.ends_with(".hlo.txt") {
            std::fs::write(dir.path().join(name), "HloModule garbage\n%%%%").unwrap();
        } else {
            std::fs::copy(&p, dir.path().join(name)).unwrap();
        }
    }
    assert!(Engine::load(dir.path()).is_err());
}

#[test]
fn impossible_demand_never_panics_the_simulator() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.constant(1.0e7, 20); // far beyond any config
    for kind in [
        PolicyKind::Diagonal,
        PolicyKind::HorizontalOnly,
        PolicyKind::VerticalOnly,
        PolicyKind::Threshold,
        PolicyKind::Oracle,
        PolicyKind::Lookahead(3),
    ] {
        let run = sim.run(kind, &trace);
        assert_eq!(run.summary.steps, 20);
        assert_eq!(
            run.summary.violations, 20,
            "{kind:?}: every step must violate under impossible demand"
        );
    }
}

#[test]
fn zero_demand_is_handled() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.constant(0.0, 10);
    let run = sim.run(PolicyKind::Diagonal, &trace);
    assert_eq!(run.summary.violations, 0);
    // with zero demand the policy drifts to the cheapest *feasible*
    // config: (H=1, medium) — (H=1, small) has L = 5.04 > l_max = 5.0,
    // so the small tier is latency-infeasible at any demand.
    assert_eq!(run.records.last().unwrap().config, Configuration::new(0, 1));
}

#[test]
fn cluster_survives_node_failures_mid_trace() {
    let cfg = ModelConfig::default_paper();
    let mut c = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        41,
    );
    let trace = TraceBuilder::paper(&cfg);
    let mut reports = Vec::new();
    for (i, w) in trace.points.iter().enumerate() {
        if i == 25 {
            // kill a node at peak load — the next reconfiguration
            // replaces the fleet
            let victim = 0;
            // (reach into the cluster through a fresh failure API)
            c.cluster_mut().fail_node(victim);
        }
        reports.push(c.tick(i, *w).unwrap());
    }
    let s = coordinator::summarize(&reports);
    assert_eq!(s.steps, 50);
    // the run must complete and keep serving most traffic overall
    assert!(s.completed_ratio > 0.8, "completed={}", s.completed_ratio);
    let cl = c.cluster();
    let total = cl.total_completed + cl.total_dropped;
    assert!((cl.total_offered - total).abs() < 1e-6 * cl.total_offered);
}

#[test]
fn fleet_funds_a_repair_after_a_des_node_loss() {
    use diagonal_scale::cluster::SubstrateKind;
    use diagonal_scale::fleet::{FleetSimulator, PriorityClass, TenantSpec};

    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::paper(&cfg);
    let specs: Vec<TenantSpec> = (0..3)
        .map(|i| {
            let class = [PriorityClass::Gold, PriorityClass::Silver, PriorityClass::Bronze][i];
            TenantSpec::from_config(&cfg, format!("t{i}"), class, base.shifted(i * 16))
        })
        .collect();
    // generous budget: the pin is that the *pipeline* carries the
    // repair end to end, not that money is scarce
    let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
    fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
    fleet.enable_explain(3);

    // inject the loss through the DES calendar: node 0 of the victim's
    // cluster dies mid-interval at its exact event time, at peak load
    let (victim, fail_tick) = (0usize, 25usize);
    let interval = ClusterParams::default().interval;
    assert!(
        fleet.tenants_mut()[victim]
            .schedule_node_failure((fail_tick as f64 + 0.5) * interval, 0),
        "DES substrate must accept a calendar-scheduled failure"
    );

    let res = fleet.run(50);

    // the failure hurt: the victim audits SLA violations once the node
    // is gone and peak demand lands on the survivors
    let hurt = fleet.tenants()[victim]
        .records()
        .iter()
        .any(|r| r.step >= fail_tick && (r.violation.latency || r.violation.throughput));
    assert!(hurt, "node loss never degraded the victim tenant");

    // ...and the loop closed: the victim proposed a move after the
    // failure and the arbiter funded it (the reconfiguration rebuilds
    // the node set, replacing the dead node)
    let repaired = fleet
        .explain_log()
        .iter()
        .any(|r| r.tenant == victim && r.step >= fail_tick && r.verdict.admitted());
    assert!(repaired, "no funded repair for the victim after the node loss");

    // graceful degradation, not collapse: everyone kept serving
    assert_eq!(res.ticks.len(), 50);
    assert!(res.report.tenants.iter().all(|t| t.summary.avg_throughput > 0.0));
}

#[test]
fn cluster_with_all_nodes_down_sheds_everything_but_survives() {
    let cfg = ModelConfig::default_paper();
    let mut cluster = ClusterSim::new(&cfg, ClusterParams::default(), 43);
    for i in 0..cluster.n_nodes() {
        cluster.fail_node(i);
    }
    let m = cluster.step(WorkloadPoint::new(5000.0, 0.3));
    assert_eq!(m.completed, 0.0);
    assert!(m.dropped > 0.0);
}

#[test]
fn malformed_config_files_are_rejected_loudly() {
    for bad in [
        "",                                      // empty
        "plane = 3\n",                           // wrong type
        "[plane]\nh_values = [8, 4]\n",          // decreasing
        "[plane]\nh_values = [1,2]\n[[plane.tiers]]\nname=\"a\"\ncpu=0.0\nram=1\nbandwidth=1\niops=1\ncost=1\n", // zero resource
    ] {
        assert!(ModelConfig::from_toml(bad).is_err(), "should reject: {bad:?}");
    }
}

#[test]
fn missing_config_file_is_a_clear_error() {
    let err = ModelConfig::from_path("/no/such/config.toml").unwrap_err();
    assert!(format!("{err:#}").contains("reading config"));
}
