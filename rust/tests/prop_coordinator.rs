//! Property tests (via the in-tree `testkit`) on the coordinator
//! invariants DESIGN.md calls out: neighbor generation, feasibility of
//! chosen configs, fallback conditions, rebalance-penalty metric
//! properties, and simulator determinism.

use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::{
    rebalance_penalty, DiagonalScale, Lookahead, Policy, PolicyContext,
};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::sla::SlaSpec;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::testkit::{choice, forall, uniform};
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint, XorShift64};

struct Fx {
    cfg: ModelConfig,
    model: SurfaceModel,
    sla: SlaSpec,
}

impl Fx {
    fn new() -> Self {
        let cfg = ModelConfig::default_paper();
        Self {
            model: SurfaceModel::from_config(&cfg),
            sla: SlaSpec::from_config(&cfg),
            cfg,
        }
    }

    fn ctx(&self) -> PolicyContext<'_> {
        PolicyContext {
            model: &self.model,
            sla: &self.sla,
            reb_h: self.cfg.policy.reb_h,
            reb_v: self.cfg.policy.reb_v,
            plan_queue: false,
            future: &[],
            budget: None,
        }
    }
}

fn random_config(rng: &mut XorShift64) -> Configuration {
    Configuration::new(rng.below(4) as usize, rng.below(4) as usize)
}

fn random_workload(rng: &mut XorShift64) -> WorkloadPoint {
    // spans infeasible-everywhere to trivially-feasible
    let lam = uniform(rng, 10.0, 60_000.0);
    WorkloadPoint::new(lam, 0.3)
}

#[test]
fn neighborhood_invariants() {
    let fx = Fx::new();
    let plane = fx.model.plane();
    forall(300, 0xA1, |_, rng| {
        let cur = random_config(rng);
        let adh = rng.next_f64() < 0.5;
        let adv = rng.next_f64() < 0.5;
        let n = plane.neighbors(&cur, adh, adv);
        assert!(n.contains(&cur), "self always included");
        assert!(n.len() <= 9);
        for c in &n {
            assert!(plane.contains(c));
            let (dh, dv) = cur.index_distance(c);
            assert!(dh <= 1 && dv <= 1, "one-step locality");
            if !adh {
                assert_eq!(dh, 0, "H frozen");
            }
            if !adv {
                assert_eq!(dv, 0, "V frozen");
            }
        }
        // row-major, no duplicates
        let flat: Vec<usize> = n.iter().map(|c| c.h_idx * 8 + c.v_idx).collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(flat.len(), sorted.len(), "no duplicates");
        assert!(flat.windows(2).all(|w| w[0] < w[1]), "row-major order");
    });
}

#[test]
fn decision_always_in_plane_and_local() {
    let fx = Fx::new();
    forall(300, 0xA2, |_, rng| {
        let cur = random_config(rng);
        let w = random_workload(rng);
        let moves = *choice(
            rng,
            &[MoveFlags::DIAGONAL, MoveFlags::HORIZONTAL_ONLY, MoveFlags::VERTICAL_ONLY],
        );
        let d = DiagonalScale::new(moves).decide(cur, w, &fx.ctx());
        assert!(fx.model.plane().contains(&d.next));
        let (dh, dv) = cur.index_distance(&d.next);
        assert!(dh <= 1 && dv <= 1, "local search moves one step");
        if !moves.allow_dh {
            assert_eq!(d.next.h_idx, cur.h_idx);
        }
        if !moves.allow_dv {
            assert_eq!(d.next.v_idx, cur.v_idx);
        }
    });
}

#[test]
fn chosen_config_feasible_iff_not_fallback() {
    let fx = Fx::new();
    forall(300, 0xA3, |_, rng| {
        let cur = random_config(rng);
        let w = random_workload(rng);
        let d = DiagonalScale::diagonal().decide(cur, w, &fx.ctx());
        let any_feasible = fx
            .model
            .plane()
            .neighbors(&cur, true, true)
            .iter()
            .any(|c| fx.model.feasible(c, w.lambda_req, &fx.sla, false));
        assert_eq!(d.fallback, !any_feasible, "fallback fires iff nothing feasible");
        if !d.fallback {
            assert!(
                fx.model.feasible(&d.next, w.lambda_req, &fx.sla, false),
                "chosen config must satisfy the SLA filter"
            );
        }
    });
}

#[test]
fn chosen_score_is_the_neighborhood_minimum() {
    let fx = Fx::new();
    forall(300, 0xA4, |_, rng| {
        let cur = random_config(rng);
        let w = random_workload(rng);
        let ctx = fx.ctx();
        let d = DiagonalScale::diagonal().decide(cur, w, &ctx);
        if d.fallback {
            return;
        }
        for c in fx.model.plane().neighbors(&cur, true, true) {
            let s = DiagonalScale::score_candidate(&cur, &c, w, &ctx);
            assert!(
                d.score <= s + 1e-3,
                "chosen {:?} score {} beaten by {:?} score {}",
                d.next,
                d.score,
                c,
                s
            );
        }
    });
}

#[test]
fn rebalance_penalty_is_a_metric() {
    forall(500, 0xA5, |_, rng| {
        let a = random_config(rng);
        let b = random_config(rng);
        let c = random_config(rng);
        let (rh, rv) = (uniform(rng, 0.0, 10.0), uniform(rng, 0.0, 10.0));
        let d = |x: &Configuration, y: &Configuration| rebalance_penalty(x, y, rh, rv);
        assert_eq!(d(&a, &a), 0.0, "identity");
        assert_eq!(d(&a, &b), d(&b, &a), "symmetry");
        assert!(d(&a, &c) <= d(&a, &b) + d(&b, &c) + 1e-5, "triangle inequality");
        assert!(d(&a, &b) >= 0.0, "non-negative");
    });
}

#[test]
fn h_moves_cost_at_least_v_moves() {
    // paper IV.D: with the default weights, a pure-H step is strictly
    // costlier than a pure-V step of the same index distance.
    let cfg = ModelConfig::default_paper();
    forall(200, 0xA6, |_, rng| {
        let a = random_config(rng);
        let dh = Configuration::new((a.h_idx + 1).min(3), a.v_idx);
        let dv = Configuration::new(a.h_idx, (a.v_idx + 1).min(3));
        if dh != a && dv != a {
            let rh = rebalance_penalty(&a, &dh, cfg.policy.reb_h, cfg.policy.reb_v);
            let rv = rebalance_penalty(&a, &dv, cfg.policy.reb_h, cfg.policy.reb_v);
            assert!(rh > rv);
        }
    });
}

#[test]
fn simulator_deterministic_on_random_traces() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);
    forall(25, 0xA7, |case, rng| {
        let trace = b.bursty(
            uniform(rng, 30.0, 100.0),
            uniform(rng, 100.0, 200.0),
            0.3,
            40,
            case as u64,
        );
        let x = sim.run(PolicyKind::Diagonal, &trace);
        let y = sim.run(PolicyKind::Diagonal, &trace);
        assert_eq!(x.records, y.records);
    });
}

#[test]
fn lookahead_depth_one_equals_greedy_when_feasible() {
    let fx = Fx::new();
    forall(200, 0xA8, |_, rng| {
        let cur = random_config(rng);
        let w = random_workload(rng);
        let ctx = fx.ctx();
        let g = DiagonalScale::diagonal().decide(cur, w, &ctx);
        let l = Lookahead::new(MoveFlags::DIAGONAL, 1).decide(cur, w, &ctx);
        if !g.fallback {
            assert_eq!(g.next, l.next);
        }
    });
}

#[test]
fn violations_monotone_in_demand_scale() {
    // scaling the whole trace up cannot reduce violations
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let b = TraceBuilder::from_config(&cfg);
    forall(20, 0xA9, |_, rng| {
        let base_level = uniform(rng, 40.0, 120.0);
        let lo = b.constant(base_level, 30);
        let hi = b.constant(base_level * 2.5, 30);
        let v_lo = sim.run(PolicyKind::Diagonal, &lo).summary.violations;
        let v_hi = sim.run(PolicyKind::Diagonal, &hi).summary.violations;
        assert!(v_hi >= v_lo, "demand x2.5: {v_lo} -> {v_hi}");
    });
}
