//! Tier-2 scale pin for the activity-proportional control plane: on a
//! sparse-activity fleet (fixed active cohort, idle sea that parks once
//! and never moves), per-tick planning work — measured by the
//! machine-independent `fresh_proposals` proxy — must track the active
//! set, not the tenant count. The bound asserted here is the ISSUE's
//! acceptance criterion: the 10240-tenant fleet does at most 4x the
//! planning work of the 512-tenant fleet over the same steady-state
//! window. Wall-clock for the same sweep lives in `benches/fleet.rs`.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::FleetSimulator;
use diagonal_scale::serverless::{sparse_activity_specs, ServerlessParams};

/// Steady-state fresh-proposal count for a sparse-activity fleet of
/// `n` tenants: 16 trace-driven + 8 bursty, everyone else flat zero.
fn steady_state_fresh(cfg: &ModelConfig, n: usize, warm: usize, window: usize) -> usize {
    let mut fleet = FleetSimulator::new(cfg, sparse_activity_specs(cfg, n, 16, 8), 1.0e6, 3);
    fleet.enable_serverless(ServerlessParams::default());
    fleet.set_recording(false);
    // park the idle sea: suspension needs idle_ticks of observed idle
    // plus a drain tick, well inside the warmup window
    for _ in 0..warm {
        fleet.tick();
    }
    (0..window).map(|_| fleet.tick().fresh_proposals).sum()
}

#[test]
fn planning_work_tracks_activity_not_fleet_size() {
    let cfg = ModelConfig::default_paper();
    let (warm, window) = (16, 96);
    let small = steady_state_fresh(&cfg, 512, warm, window);
    let large = steady_state_fresh(&cfg, 10240, warm, window);
    assert!(
        large <= 4 * small,
        "10240-tenant planning work ({large} fresh proposals over {window} ticks) exceeds \
         4x the 512-tenant case ({small})"
    );
    // the bound must come from caching, not from a degenerate window:
    // an always-replan fleet would propose n times per tick
    assert!(
        large < 10240 * window / 8,
        "dirty queue barely cached at 10240 tenants ({large} fresh proposals)"
    );
    assert!(small > 0, "no planning work measured — the active cohort never proposed");
}
