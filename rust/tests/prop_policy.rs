//! PR-5 parity pins for the proposal-first policy API: for EVERY
//! in-tree policy, `propose().top()` must equal `decide()`
//! bit-for-bit (same target, same score bits, same fallback flag),
//! candidate lists must be sorted by ranking score with no duplicate
//! configurations, and gains must be non-negative (zero on infeasible
//! entries). Stateful policies (forecast lookahead) are driven as two
//! instances in lockstep so the comparison never desynchronizes their
//! predictors.

use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::forecast::{Holt, SeasonalNaive};
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::{
    BudgetHint, DiagonalScale, ForecastLookahead, Lookahead, Oracle, Policy, PolicyContext,
    StaticPolicy, Threshold,
};
use diagonal_scale::sla::SlaSpec;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::WorkloadPoint;

fn builders() -> Vec<(&'static str, fn() -> Box<dyn Policy>)> {
    vec![
        ("diagonal", || Box::new(DiagonalScale::diagonal())),
        ("horizontal-only", || Box::new(DiagonalScale::horizontal_only())),
        ("vertical-only", || Box::new(DiagonalScale::vertical_only())),
        ("lookahead-1", || Box::new(Lookahead::new(MoveFlags::DIAGONAL, 1))),
        ("lookahead-3", || Box::new(Lookahead::new(MoveFlags::DIAGONAL, 3))),
        ("forecast-holt", || {
            Box::new(ForecastLookahead::new(MoveFlags::DIAGONAL, 3, Holt::default_tuned(), 0.3))
        }),
        ("forecast-seasonal", || {
            Box::new(ForecastLookahead::new(MoveFlags::DIAGONAL, 3, SeasonalNaive::new(10), 0.3))
        }),
        ("threshold", || Box::new(Threshold::default())),
        ("oracle", || Box::new(Oracle)),
        ("static", || Box::new(StaticPolicy)),
    ]
}

#[test]
fn propose_top_matches_decide_bit_for_bit_for_every_policy() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    for (name, build) in builders() {
        forall(40, 0x9201, |case, rng| {
            // two fresh instances driven in lockstep over one random
            // trajectory (stateful policies update per call)
            let mut a = build();
            let mut b = build();
            let mut cur = Configuration::new(rng.below(4) as usize, rng.below(4) as usize);
            let budget = if rng.next_f64() < 0.5 {
                Some(BudgetHint::new(uniform(rng, 0.0, 4.0), uniform(rng, 0.0, 4.0)))
            } else {
                None
            };
            let plan_queue = rng.next_f64() < 0.3;
            let future: Vec<WorkloadPoint> = (0..3)
                .map(|_| WorkloadPoint::new(uniform(rng, 10.0, 40_000.0), 0.3))
                .collect();
            for step in 0..8 {
                let w = WorkloadPoint::new(uniform(rng, 10.0, 40_000.0), 0.3);
                let ctx = PolicyContext {
                    model: &model,
                    sla: &sla,
                    reb_h: cfg.policy.reb_h,
                    reb_v: cfg.policy.reb_v,
                    plan_queue,
                    future: &future,
                    budget,
                };
                let d = a.decide(cur, w, &ctx);
                let p = b.propose(cur, w, &ctx);
                let top = *p.top().expect("every policy ranks at least one candidate");
                assert_eq!(top.to, d.next, "{name} case {case} step {step}: top != decide");
                assert_eq!(
                    top.score.to_bits(),
                    d.score.to_bits(),
                    "{name} case {case} step {step}: score bits differ ({} vs {})",
                    top.score,
                    d.score
                );
                assert_eq!(p.fallback, d.fallback, "{name}: fallback flag diverged");
                assert_eq!(p.decision(), d, "{name}: derived decision diverged");
                assert!(p.is_ranked(), "{name}: candidates not sorted by score");
                for (i, x) in p.candidates.iter().enumerate() {
                    assert!(model.plane().contains(&x.to), "{name}: off-plane candidate");
                    assert!(x.gain >= 0.0, "{name}: negative gain {}", x.gain);
                    if !x.feasible() {
                        assert_eq!(x.gain, 0.0, "{name}: infeasible candidate claims gain");
                    }
                    let expect_cost = model.cost(&x.to);
                    assert!(
                        (x.cost_to - expect_cost).abs() < 1e-6,
                        "{name}: candidate cost drifted from the surface"
                    );
                    for y in &p.candidates[i + 1..] {
                        assert_ne!(x.to, y.to, "{name}: duplicate configuration in ranking");
                    }
                }
                cur = d.next;
            }
        });
    }
}

/// The enumerating policies (local search + lookahead family) must rank
/// the ENTIRE neighborhood — holding included — so downstream
/// distillation (fleet alternatives, sheds, stepping stones) never
/// needs a second enumeration.
#[test]
fn enumerating_policies_rank_the_whole_neighborhood() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    forall(60, 0x9202, |_, rng| {
        let cur = Configuration::new(rng.below(4) as usize, rng.below(4) as usize);
        let w = WorkloadPoint::new(uniform(rng, 10.0, 40_000.0), 0.3);
        let ctx = PolicyContext {
            model: &model,
            sla: &sla,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: false,
            future: &[],
            budget: None,
        };
        let neighborhood = model.plane().neighbors(&cur, true, true);
        for mut policy in [
            Box::new(DiagonalScale::diagonal()) as Box<dyn Policy>,
            Box::new(Lookahead::new(MoveFlags::DIAGONAL, 2)),
        ] {
            let p = policy.propose(cur, w, &ctx);
            assert_eq!(
                p.candidates.len(),
                neighborhood.len(),
                "{}: proposal must cover the whole neighborhood",
                policy.name()
            );
            for n in &neighborhood {
                assert!(
                    p.candidates.iter().any(|c| c.to == *n),
                    "{}: neighbor {:?} missing from the proposal",
                    policy.name(),
                    n
                );
            }
        }
    });
}
