//! Property tests for the serverless tier (PR 6): across randomized
//! fleet shapes, budgets, and idle mixes,
//!
//! 1. no tenant is ever lost across suspend/resume round-trips — the
//!    storage registration survives, lifecycle counters stay paired,
//!    and nobody sticks in a transitional state once the calendar is
//!    empty;
//! 2. a suspended (or draining) tenant accrues *only* storage cost;
//! 3. a resume always completes before the tenant serves load — no
//!    throughput leaks out of a cold-start window;
//! 4. every decision is deterministic per seed.
//!
//! Lifecycle legality is asserted tick by tick: the only edges are
//! Active→Draining→Suspended→Resuming→Active (plus self-loops), and a
//! Suspended tenant never jumps straight to Active.

use diagonal_scale::fleet::FleetSimulator;
use diagonal_scale::serverless::{mostly_idle_specs, Lifecycle, ServerlessParams};
use diagonal_scale::testkit::forall;
use diagonal_scale::ModelConfig;

struct Shape {
    n: usize,
    idle_fraction: f32,
    budget: f32,
    steps: usize,
}

fn shape(case: usize, rng: &mut diagonal_scale::workload::XorShift64) -> Shape {
    let n = 4 + (rng.below(9) as usize); // 4..=12 tenants
    Shape {
        n,
        idle_fraction: [0.5, 0.75, 1.0][case % 3],
        // alternate between an uncapped fleet and a tight one where
        // wake denials and retries actually happen
        budget: if case % 2 == 0 { 1.0e6 } else { 0.6 * n as f32 },
        steps: 40 + (rng.below(41) as usize), // 40..=80 ticks
    }
}

fn build(cfg: &ModelConfig, s: &Shape) -> FleetSimulator {
    let mut fleet =
        FleetSimulator::new(cfg, mostly_idle_specs(cfg, s.n, s.idle_fraction), s.budget, 3);
    fleet.enable_serverless(ServerlessParams::default());
    fleet
}

/// Post-tick lifecycle snapshot of every tenant.
fn snapshot(fleet: &FleetSimulator) -> Vec<Lifecycle> {
    fleet.tenants().iter().map(|t| t.lifecycle().expect("serverless fleet")).collect()
}

#[test]
fn prop_lifecycle_edges_are_legal_and_no_tenant_is_lost() {
    let cfg = ModelConfig::default_paper();
    forall(6, 0xC0FFEE, |case, rng| {
        let s = shape(case, rng);
        let mut fleet = build(&cfg, &s);
        let mut prev = snapshot(&fleet);
        for _ in 0..s.steps {
            fleet.tick();
            let now = snapshot(&fleet);
            assert_eq!(now.len(), s.n, "a tenant vanished mid-run");
            for (id, (&p, &q)) in prev.iter().zip(&now).enumerate() {
                let legal = match p {
                    Lifecycle::Active => {
                        matches!(q, Lifecycle::Active | Lifecycle::Draining)
                    }
                    Lifecycle::Draining => {
                        matches!(q, Lifecycle::Suspended)
                    }
                    // a wake must pass through Resuming — Suspended
                    // never jumps straight back to Active
                    Lifecycle::Suspended => {
                        matches!(q, Lifecycle::Suspended | Lifecycle::Resuming { .. })
                    }
                    Lifecycle::Resuming { .. } => {
                        matches!(q, Lifecycle::Active | Lifecycle::Resuming { .. })
                    }
                };
                assert!(legal, "case {case} tenant {id}: illegal edge {p:?} -> {q:?}");
            }
            prev = now;
        }
        // round-trip accounting: at most one suspension can be open,
        // and the storage registration survives every round-trip
        let storage = fleet.storage().expect("storage service");
        for t in fleet.tenants() {
            let sv = t.serverless().unwrap();
            assert!(
                sv.resumes <= sv.suspends,
                "case {case} {}: more wakes than suspensions",
                t.name()
            );
            assert!(
                storage.stored_gb(t.id) > 0.0,
                "case {case} {}: pages lost from the storage tier",
                t.name()
            );
        }
        // once the calendar is empty nobody may be stuck mid-resume
        if fleet.pending_resumes() == 0 {
            assert!(
                fleet.tenants().iter().all(|t| !matches!(
                    t.lifecycle(),
                    Some(Lifecycle::Resuming { .. })
                )),
                "case {case}: tenant stuck Resuming with an empty calendar"
            );
        }
    });
}

#[test]
fn prop_suspended_tenants_accrue_only_storage_cost() {
    let cfg = ModelConfig::default_paper();
    forall(6, 0xBEEF, |case, rng| {
        let s = shape(case, rng);
        let mut fleet = build(&cfg, &s);
        for _ in 0..s.steps {
            fleet.tick();
            for t in fleet.tenants() {
                match t.lifecycle().unwrap() {
                    Lifecycle::Draining | Lifecycle::Suspended => assert!(
                        (t.cost() - t.storage_cost()).abs() < 1e-6,
                        "case {case} {}: parked tenant billed {} vs storage {}",
                        t.name(),
                        t.cost(),
                        t.storage_cost()
                    ),
                    // cold starts are *priced*: compute is paid from
                    // the moment the wake is admitted
                    Lifecycle::Active | Lifecycle::Resuming { .. } => assert!(
                        t.cost() > t.storage_cost(),
                        "case {case} {}: live tenant priced below storage",
                        t.name()
                    ),
                }
            }
        }
    });
}

#[test]
fn prop_resume_completes_before_any_load_is_served() {
    let cfg = ModelConfig::default_paper();
    forall(6, 0xD1CE, |case, rng| {
        let s = shape(case, rng);
        let mut fleet = build(&cfg, &s);
        // parked[id] after tick t => tenant id cannot serve tick t+1
        // (a Resuming{until} window only re-opens service at `until`)
        let mut parked: Vec<Option<bool>> = vec![None; s.n];
        for step in 0..s.steps {
            fleet.tick();
            for (id, was_parked) in parked.iter().enumerate() {
                if *was_parked == Some(true) {
                    let rec = &fleet.tenants()[id].records()[step];
                    assert_eq!(
                        rec.throughput, 0.0,
                        "case {case} tenant {id} served tick {step} while parked"
                    );
                }
            }
            for (id, t) in fleet.tenants().iter().enumerate() {
                parked[id] = Some(match t.lifecycle().unwrap() {
                    Lifecycle::Draining | Lifecycle::Suspended => true,
                    Lifecycle::Resuming { until } => until > step + 1,
                    Lifecycle::Active => false,
                });
            }
        }
    });
}

#[test]
fn prop_decisions_are_deterministic_per_seed() {
    let cfg = ModelConfig::default_paper();
    forall(4, 0xFACE, |case, rng| {
        let s = shape(case, rng);
        let a = build(&cfg, &s).run(s.steps);
        let b = build(&cfg, &s).run(s.steps);
        assert_eq!(a.ticks, b.ticks, "case {case}: tick streams diverged");
        let (ra, rb) = (&a.report.tenants, &b.report.tenants);
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.suspended_ticks, y.suspended_ticks);
            assert_eq!(x.resumes, y.resumes);
            assert_eq!(x.summary.violations, y.summary.violations);
        }
    });
}
