//! Round-trip pin for the pull-based export registry: every name in
//! the versioned snapshot `config/metrics_v1.names` must appear in the
//! Prometheus text and JSON renderings of a real fleet run, and the
//! registry must expose exactly that set — no unpinned strays. The
//! placement and coordinator exporters are exercised through the same
//! registry via `merge_from`.

use std::collections::BTreeSet;

use diagonal_scale::cluster::ClusterParams;
use diagonal_scale::config::ModelConfig;
use diagonal_scale::coordinator::{self, native_coordinator};
use diagonal_scale::fleet::FleetSimulator;
use diagonal_scale::metrics::{names, MetricsRegistry, METRICS_SCHEMA};
use diagonal_scale::placement::{self, PlacementConfig, PlacementSim};
use diagonal_scale::policy::DiagonalScale;
use diagonal_scale::serverless::{mostly_idle_specs, ServerlessParams};
use diagonal_scale::workload::TraceBuilder;

/// The pinned name set, straight off disk (the same file simlint's S2
/// rule and the names.rs snapshot test read).
fn pinned_names() -> BTreeSet<String> {
    let raw = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/config/metrics_v1.names"
    ))
    .expect("config/metrics_v1.names must exist");
    raw.lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

#[test]
fn fleet_export_round_trips_every_pinned_name() {
    let pinned = pinned_names();
    assert_eq!(pinned.len(), names::ALL.len(), "snapshot and table must agree");

    let cfg = ModelConfig::default_paper();
    let mut fleet = FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 16, 0.75), 1.0e6, 3);
    fleet.enable_serverless(ServerlessParams::default());
    fleet.enable_streaming_metrics(8);
    fleet.run(60);

    let reg = fleet.export_metrics();
    // declare_all() backstops every pinned name, live series overwrite:
    // exposition is exactly the snapshot, nothing more, nothing less
    assert_eq!(reg.metric_names(), pinned, "registry names != snapshot");

    let text = reg.render_prometheus();
    let json = reg.render_json();
    assert!(json.starts_with(&format!("{{\"schema\":\"{METRICS_SCHEMA}\"")));
    for name in &pinned {
        assert!(
            text.contains(name.as_str()),
            "{name} missing from prometheus exposition"
        );
        assert!(json.contains(&format!("\"{name}")), "{name} missing from JSON");
    }
    // HELP/TYPE headers render once per metric family
    assert!(text.contains(&format!("# TYPE {} counter", names::FLEET_TICKS_TOTAL)));
    assert!(text.contains(&format!("# TYPE {} summary", names::FLEET_LATENCY_SECONDS)));

    // a real run drove the sketches: the HLL-backed gauges are live
    assert!(reg.gauge_value(names::FLEET_ACTIVE_TENANTS_ESTIMATE, &[]).unwrap() > 0.0);
    assert_eq!(reg.counter_value(names::FLEET_TICKS_TOTAL, &[]), Some(60));
}

#[test]
fn export_is_idempotent() {
    let cfg = ModelConfig::default_paper();
    let mut fleet = FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 8, 0.5), 1.0e6, 3);
    fleet.run(30);
    let first = fleet.export_metrics().render_prometheus();
    // a second pull must not re-fold the rollups (sketch merges are
    // not idempotent at the accumulator level — the guard makes them so)
    let second = fleet.export_metrics().render_prometheus();
    assert_eq!(first, second);
}

#[test]
fn placement_and_coordinator_export_into_one_registry() {
    let cfg = ModelConfig::default_paper();
    let mut reg = MetricsRegistry::new();
    reg.declare_all();

    let mut sim = PlacementSim::packed(
        &cfg,
        placement::constant_tenant_specs(&cfg, 12),
        1.0e6,
        3,
        PlacementConfig::default(),
    );
    sim.run(20);
    sim.export_metrics(&mut reg);
    assert!(reg.gauge_value(names::PLACEMENT_HOSTS, &[]).unwrap() >= 1.0);
    // the hosts HLL saw every touched cluster id — with 12 tenants
    // packed onto at least one host the estimate must be positive
    assert!(reg.gauge_value(names::PLACEMENT_HOSTS_TOUCHED_ESTIMATE, &[]).unwrap() > 0.0);
    assert!(reg.gauge_value(names::PLACEMENT_SPEND_HOURLY, &[]).unwrap() > 0.0);

    let mut coord = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        42,
    );
    let reports = coord
        .run_trace(&TraceBuilder::paper(&cfg))
        .expect("coordinator trace run");
    coordinator::export_metrics(&reports, &mut reg);
    assert_eq!(
        reg.gauge_value(names::COORDINATOR_STEPS, &[]),
        Some(reports.len() as f64)
    );
    let hist = reg.histogram(names::COORDINATOR_P99_SECONDS, &[]).unwrap();
    assert_eq!(hist.len(), reports.len() as u64);

    // merging a second registry keeps the pinned name set closed
    let mut other = MetricsRegistry::new();
    other.declare_all();
    other.merge_from(&reg);
    assert_eq!(other.metric_names(), reg.metric_names());
    for name in pinned_names() {
        assert!(other.metric_names().contains(&name), "{name} lost in merge");
    }
}
