//! Substrate parity properties: the event-driven engine and the legacy
//! sampling engine must conserve ops, be deterministic per seed, and
//! agree with each other — exactly below the sampling cap (same RNG
//! consumption order), and within tolerance once mid-step event timing
//! (rebalance windows, compaction) comes into play.

use diagonal_scale::cluster::{
    ClusterParams, ClusterSim, ClusterStepMetrics, EventSim, Substrate,
};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::coordinator::{event_coordinator, native_coordinator, summarize};
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::DiagonalScale;
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn close(a: f64, b: f64, rel: f64) -> bool {
    (a - b).abs() <= rel * a.abs().max(b.abs()).max(1e-9)
}

#[test]
fn conservation_holds_in_both_engines() {
    let cfg = ModelConfig::default_paper();
    forall(10, 0xC0, |_, rng| {
        let seed = rng.next_u64();
        let lam = uniform(rng, 50.0, 8_000.0);
        let h = rng.below(4) as usize;
        let v = rng.below(4) as usize;
        let mut sampling = ClusterSim::new(&cfg, ClusterParams::default(), seed);
        let mut event = EventSim::new(&cfg, ClusterParams::default(), seed);
        for sub in [
            &mut sampling as &mut dyn Substrate,
            &mut event as &mut dyn Substrate,
        ] {
            sub.apply(Configuration::new(h, v));
            for _ in 0..5 {
                sub.step(WorkloadPoint::new(lam, 0.3));
            }
            let st = sub.observe();
            assert!(
                (st.total_offered - st.total_completed - st.total_dropped).abs()
                    <= 1e-6 * st.total_offered.max(1.0),
                "offered={} completed={} dropped={}",
                st.total_offered,
                st.total_completed,
                st.total_dropped
            );
        }
    });
}

#[test]
fn per_seed_determinism_in_both_engines() {
    let cfg = ModelConfig::default_paper();
    forall(6, 0xD1, |_, rng| {
        let seed = rng.next_u64();
        let lam = uniform(rng, 100.0, 6_000.0);
        let run_sampling = |mut sim: ClusterSim| -> Vec<ClusterStepMetrics> {
            sim.apply(Configuration::new(2, 1));
            (0..4).map(|_| sim.step(WorkloadPoint::new(lam, 0.3))).collect()
        };
        assert_eq!(
            run_sampling(ClusterSim::new(&cfg, ClusterParams::default(), seed)),
            run_sampling(ClusterSim::new(&cfg, ClusterParams::default(), seed))
        );
        let run_event = |mut sim: EventSim| -> Vec<ClusterStepMetrics> {
            sim.apply(Configuration::new(2, 1));
            (0..4).map(|_| sim.step(WorkloadPoint::new(lam, 0.3))).collect()
        };
        assert_eq!(
            run_event(EventSim::new(&cfg, ClusterParams::default(), seed)),
            run_event(EventSim::new(&cfg, ClusterParams::default(), seed))
        );
    });
}

#[test]
fn engines_agree_below_the_sampling_cap() {
    // no compaction and a settled cluster: the two engines consume the
    // RNG in the same order and must measure (near-)identically
    let cfg = ModelConfig::default_paper();
    forall(8, 0xE2, |_, rng| {
        let seed = rng.next_u64();
        let lam = uniform(rng, 100.0, 15_000.0);
        let zipf = if rng.below(2) == 0 { 0.0 } else { 0.99 };
        let params = ClusterParams { zipf_s: zipf, ..ClusterParams::default() };
        let mut a = ClusterSim::new(&cfg, params, seed);
        let mut b = EventSim::new(&cfg, params, seed);
        a.apply(Configuration::new(2, 2));
        b.apply(Configuration::new(2, 2));
        // burn past the shared reconfiguration window and let queues
        // drain so carried-over server state is equal
        for _ in 0..3 {
            a.step(WorkloadPoint::new(200.0, 0.3));
            b.step(WorkloadPoint::new(200.0, 0.3));
        }
        for _ in 0..3 {
            let ma = a.step(WorkloadPoint::new(lam, 0.3));
            let mb = b.step(WorkloadPoint::new(lam, 0.3));
            assert!(close(ma.utilization, mb.utilization, 1e-9), "{ma:?} vs {mb:?}");
            assert!(close(ma.completed, mb.completed, 1e-3), "{ma:?} vs {mb:?}");
            assert!(close(ma.avg_latency, mb.avg_latency, 1e-3), "{ma:?} vs {mb:?}");
        }
    });
}

#[test]
fn coordinated_paper_trace_parity() {
    // the full control loop on both engines: planning consumes only the
    // offered load, so decisions must be identical; measurements agree
    // within the tolerance left by mid-step window timing
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);
    let mut a = native_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        11,
    );
    let mut b = event_coordinator(
        &cfg,
        Box::new(DiagonalScale::diagonal()),
        ClusterParams::default(),
        11,
    );
    let ra = a.run_trace(&trace).unwrap();
    let rb = b.run_trace(&trace).unwrap();

    let ca: Vec<_> = ra.iter().map(|r| r.served_config).collect();
    let cb: Vec<_> = rb.iter().map(|r| r.served_config).collect();
    assert_eq!(ca, cb, "engines must induce the same scaling trajectory");

    for (x, y) in ra.iter().zip(&rb) {
        assert!(
            close(x.metrics.utilization, y.metrics.utilization, 1e-6),
            "step {}: utilization {} vs {}",
            x.step,
            x.metrics.utilization,
            y.metrics.utilization
        );
    }

    let sa = summarize(&ra);
    let sb = summarize(&rb);
    assert!(
        (sa.completed_ratio - sb.completed_ratio).abs() < 0.05,
        "completed ratio: sampling {} vs event {}",
        sa.completed_ratio,
        sb.completed_ratio
    );
}

#[test]
fn compaction_modes_agree_on_throughput_within_tolerance() {
    // compaction windows toggle mid-step in the event engine but at
    // step granularity in the sampling engine — aggregate completion
    // must still line up
    let cfg = ModelConfig::default_paper();
    let params = ClusterParams {
        compaction_period: 5.0,
        compaction_duration: 1.0,
        compaction_degradation: 0.5,
        ..ClusterParams::default()
    };
    let mut a = ClusterSim::new(&cfg, params, 31);
    let mut b = EventSim::new(&cfg, params, 31);
    for _ in 0..20 {
        a.step(WorkloadPoint::new(3_000.0, 0.3));
        b.step(WorkloadPoint::new(3_000.0, 0.3));
    }
    let ra = a.total_completed / a.total_offered;
    let rb = b.total_completed / b.total_offered;
    assert!((ra - rb).abs() < 0.02, "sampling {ra} vs event {rb}");
    assert!(ra > 0.9 && rb > 0.9, "sampling {ra} vs event {rb}");
}

#[test]
fn event_engine_simulates_every_arrival_above_the_sampling_cap() {
    let cfg = ModelConfig::default_paper();
    let mut e = EventSim::new(&cfg, ClusterParams::default(), 17);
    e.apply(Configuration::new(3, 3));
    for _ in 0..3 {
        e.step(WorkloadPoint::new(500.0, 0.3));
    }
    // well above the sampling engine's default 20k cap
    let m = e.step(WorkloadPoint::new(30_000.0, 0.3));
    assert!(m.offered > 29_000.0);
    assert!(close(m.completed + m.dropped, m.offered, 1e-9), "{m:?}");
    let st = Substrate::observe(&e);
    assert!(
        (st.total_offered - st.total_completed - st.total_dropped).abs()
            <= 1e-6 * st.total_offered
    );
}
