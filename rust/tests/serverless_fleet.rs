//! Pinned serverless-tier scenarios (PR 6 acceptance):
//!
//! 1. A 64-tenant mostly-idle fleet under scale-to-zero must cost
//!    strictly (and structurally: >= 20%) less than the same fleet
//!    always-on, with the extra SLA-violation ticks bounded by the
//!    cold-start accounting: each wake can cost at most the detection
//!    tick plus the cold-start window.
//! 2. A correlated wake storm (every idle tenant bursts at the same
//!    tick) under a budget that cannot fund the whole cohort must
//!    resolve with zero Gold-class starvation: Gold wakes are funded
//!    first (class-ordered repair pass), Bronze waits, and the fleet
//!    settles back to full suspension.
//!
//! Both scenarios also pin that cold-start windows are visible as DES
//! calendar events: every admitted wake opens exactly one window and
//! every window closes exactly once (`Event::ResumeEnd`).

use diagonal_scale::fleet::{FleetResult, FleetSimulator, PriorityClass};
use diagonal_scale::serverless::{mostly_idle_specs, wake_storm_specs, ServerlessParams};
use diagonal_scale::{Configuration, ModelConfig, SurfaceModel};

fn total_cost(res: &FleetResult) -> f64 {
    res.ticks.iter().map(|t| t.spend as f64).sum()
}

fn total_violations(res: &FleetResult) -> usize {
    res.report.tenants.iter().map(|t| t.summary.violations).sum()
}

/// Started wakes (counted at `begin_resume`, so wakes whose window is
/// still open at the end of the run are included).
fn total_resumes(fleet: &FleetSimulator) -> usize {
    fleet.tenants().iter().filter_map(|t| t.serverless()).map(|s| s.resumes).sum()
}

#[test]
fn serverless_cuts_mostly_idle_fleet_cost_at_bounded_violations() {
    let cfg = ModelConfig::default_paper();
    let (n, idle_fraction, steps) = (64usize, 0.75f32, 100usize);
    let budget = 1.0e6f32; // uncapped: this pin is about cost, not admission

    let mut always_on =
        FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, idle_fraction), budget, 3);
    let base = always_on.run(steps);

    let mut fleet =
        FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, idle_fraction), budget, 3);
    fleet.enable_serverless(ServerlessParams::default());
    let res = fleet.run(steps);

    // the fleet actually exercised the tier
    let peak_suspended = res.ticks.iter().map(|t| t.suspended).max().unwrap_or(0);
    assert!(
        peak_suspended >= (n as f32 * idle_fraction) as usize / 2,
        "scale-to-zero never engaged (peak suspended {peak_suspended})"
    );
    let resumes = total_resumes(&fleet);
    let resume_ends: usize = res.ticks.iter().map(|t| t.resume_ends).sum();
    assert!(resumes > 0, "no burst ever woke a suspended tenant");
    // every admitted wake opened exactly one calendar window; every
    // closed window fired exactly one ResumeEnd event
    assert_eq!(
        resumes,
        resume_ends + fleet.pending_resumes(),
        "calendar windows out of balance with admitted wakes"
    );

    // the headline: serverless strictly — and structurally — cheaper.
    // Idle tenants pay ~storage (two orders below the cheapest compute
    // tier), so the saving is far past any float noise.
    let (base_cost, sv_cost) = (total_cost(&base), total_cost(&res));
    assert!(
        sv_cost < base_cost,
        "serverless must undercut always-on: {sv_cost:.1} vs {base_cost:.1}"
    );
    assert!(
        sv_cost < 0.8 * base_cost,
        "saving should be structural, not marginal: {sv_cost:.1} vs {base_cost:.1}"
    );

    // bounded extra violations: active tenants decide identically in
    // both runs (the storage shift is rank-preserving and the budget
    // never binds), so every extra violation tick belongs to a wake —
    // at most the detection tick plus the cold-start window per wake.
    let max_cold = fleet.tenants().iter().map(|t| t.cold_start_ticks()).max().unwrap_or(0);
    assert!(max_cold >= 1, "cold starts must take at least one tick");
    let bound = total_violations(&base) + resumes * (max_cold + 2);
    assert!(
        total_violations(&res) <= bound,
        "violations {} exceed the cold-start bound {} (base {}, {} wakes, cold {})",
        total_violations(&res),
        bound,
        total_violations(&base),
        resumes,
        max_cold
    );
}

#[test]
fn wake_storm_resolves_with_zero_gold_starvation() {
    let cfg = ModelConfig::default_paper();
    // every tenant idle: the storm is the only demand, so the budget
    // squeeze below is exact and deterministic. The storm spans the
    // default one-tick cold-start window exactly (detection tick +
    // window), so woken tenants come back to zero demand and re-park
    // through the always-admitted shrink pass — while denied Bronze
    // wakes keep the repair pass unmet through the whole burst.
    let (n, storm_at, storm_width, steps) = (12usize, 20usize, 2usize, 45usize);
    let build = |budget: f32| {
        let mut f = FleetSimulator::new(
            &cfg,
            wake_storm_specs(&cfg, n, 1.0, storm_at, storm_width),
            budget,
            3,
        );
        f.enable_serverless(ServerlessParams::default());
        f
    };

    // Budget: parked storage for everyone plus exactly the Gold and
    // Silver wake deltas (a wake's spend delta is the compute cost of
    // the clearing config; the storage term cancels) plus half a wake
    // of slack — the Bronze third cannot fit. The clearing config for
    // the storm burst (intensity 30 × thr_factor) is (H=2, medium):
    // (H=1, medium) tops out below the burst and (H=2, small) clears
    // throughput but not the latency bound.
    let storage_total = build(1.0e6).storage().unwrap().total_storage_cost();
    let wake_delta = SurfaceModel::from_config(&cfg).cost(&Configuration::new(1, 1));
    let budget = storage_total + wake_delta * (2.0 * (n as f32 / 3.0) + 0.5);

    let mut fleet = build(budget);
    let res = fleet.run(steps);

    // the whole cohort reached suspension before the storm hit
    assert_eq!(
        res.ticks[storm_at - 1].suspended, n,
        "cohort not fully suspended before the storm"
    );
    // the storm opened cold-start windows and every window closed
    let resume_ends: usize = res.ticks.iter().map(|t| t.resume_ends).sum();
    assert!(res.ticks.iter().any(|t| t.resuming > 0), "no cold-start window opened");
    assert_eq!(fleet.pending_resumes(), 0, "a cold-start window never closed");
    assert_eq!(total_resumes(&fleet), resume_ends);

    // zero Gold starvation: every Gold tenant woke, un-denied; the
    // squeeze was real — it landed entirely on the Bronze class
    let mut bronze_denied = 0usize;
    for t in &res.report.tenants {
        match t.class {
            PriorityClass::Gold => {
                assert_eq!(t.denied, 0, "{}: Gold wake denied under the storm", t.name);
                assert!(t.resumes >= 1, "{}: Gold tenant never resumed", t.name);
                // the wake cost at most the detection tick + the window
                assert!(
                    t.summary.violations <= 3,
                    "{}: {} violation ticks — Gold starved through the storm",
                    t.name,
                    t.summary.violations
                );
            }
            PriorityClass::Bronze => bronze_denied += t.denied,
            PriorityClass::Silver => {}
        }
    }
    assert!(
        bronze_denied > 0,
        "the budget never bit — the storm test is not exercising contention"
    );

    // the storm resolves: once the burst passes, woken tenants drain
    // back to storage-only and the fleet ends fully suspended again
    assert_eq!(
        res.ticks.last().unwrap().suspended, n,
        "fleet did not settle back to suspension after the storm"
    );
}

#[test]
fn serverless_fleet_is_deterministic() {
    let cfg = ModelConfig::default_paper();
    let build = || {
        let mut f =
            FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 16, 0.75), 6.0, 3);
        f.enable_serverless(ServerlessParams::default());
        f
    };
    let a = build().run(80);
    let b = build().run(80);
    assert_eq!(a.ticks, b.ticks, "serverless fleet runs must be reproducible");
}
