//! Scenario-subsystem properties: generator determinism across seeds,
//! the cross-tenant correlation coefficient actually realized by the
//! mixture construction, the Pareto size tail pinned to its closed
//! form, zone-outage schedules hitting exactly the mapped nodes, the
//! partition model's movement-GB invariants (moved ≤ flat `tenant_gb`,
//! equality when all shards move) — and one pinned comparison test per
//! named preset (planning-vs-flat for the fleet presets,
//! packed-vs-dedicated for heavy-tail), per the CONTRIBUTING rule that
//! a preset without a pinned comparison is not a preset.

use diagonal_scale::cluster::{ClusterParams, SubstrateKind};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    BudgetArbiter, ClassEnvelopes, FleetResult, FleetSimulator, ForecastKind, TenantSpec,
};
use diagonal_scale::placement::{constant_tenant_specs, PlacementConfig, PlacementSim};
use diagonal_scale::scenario::{self, correlated_flags, pareto, pareto_sizes, ShardModel, ZoneMap};
use diagonal_scale::workload::XorShift64;

// ---------------------------------------------------------------------
// generators
// ---------------------------------------------------------------------

#[test]
fn generators_are_deterministic_in_their_seed() {
    let cfg = ModelConfig::default_paper();
    let a = scenario::flash_crowd_specs(&cfg, 8, 0.8, 30, 4, 60, 7);
    let b = scenario::flash_crowd_specs(&cfg, 8, 0.8, 30, 4, 60, 7);
    assert_eq!(a, b, "flash-crowd specs drifted under the same seed");
    let a = pareto_sizes(64, 1.3, 0.05, 1.0, 1);
    let b = pareto_sizes(64, 1.3, 0.05, 1.0, 1);
    assert_eq!(a, b, "pareto sizes drifted under the same seed");
    // a different seed is a different fleet (XorShift64 streams from
    // distinct states never coincide index-for-index)
    let c = pareto_sizes(64, 1.3, 0.05, 1.0, 2);
    assert_ne!(a, c, "the seed is not reaching the generator");
}

/// The mixture construction promises pairwise indicator correlation
/// exactly `rho` (each tenant copies a common Bernoulli(p) draw with
/// probability `sqrt(rho)`). Estimate it from a long seeded sample and
/// require the estimate within tolerance — the coefficient is realized,
/// not just documented.
#[test]
fn correlation_coefficient_is_realized_within_tolerance() {
    fn estimate(rho: f64, seed: u64) -> f64 {
        let p = 0.3;
        let draws = 20_000;
        let mut rng = XorShift64::new(seed);
        let (mut s0, mut s1, mut s01) = (0.0f64, 0.0f64, 0.0f64);
        for _ in 0..draws {
            let f = correlated_flags(2, p, rho, &mut rng);
            let x = if f[0] { 1.0 } else { 0.0 };
            let y = if f[1] { 1.0 } else { 0.0 };
            s0 += x;
            s1 += y;
            s01 += x * y;
        }
        let n = draws as f64;
        let (m0, m1) = (s0 / n, s1 / n);
        let cov = s01 / n - m0 * m1;
        cov / ((m0 * (1.0 - m0)).sqrt() * (m1 * (1.0 - m1)).sqrt())
    }
    for (rho, seed) in [(0.0, 0xC0441), (0.5, 0xC0442), (0.9, 0xC0443)] {
        let est = estimate(rho, seed);
        assert!((est - rho).abs() < 0.06, "requested rho {rho}, sample estimate {est:.4}");
    }
}

/// Pareto(alpha, x_min) tail pinned to the closed form:
/// P(X > k·x_min) = k^(-alpha). 20k seeded draws put the sample
/// fraction within a >10-sigma band of the exact value.
#[test]
fn pareto_tail_matches_the_closed_form() {
    let (alpha, x_min) = (1.3f64, 0.05f64);
    let mut rng = XorShift64::new(0xA1FA);
    let draws = 20_000;
    let over = (0..draws)
        .filter(|_| pareto(&mut rng, alpha, x_min) > 4.0 * x_min)
        .count();
    let frac = over as f64 / draws as f64;
    let exact = 4.0f64.powf(-alpha); // ≈ 0.1649
    assert!((frac - exact).abs() < 0.03, "tail fraction {frac:.4} vs closed form {exact:.4}");
}

// ---------------------------------------------------------------------
// fault schedules
// ---------------------------------------------------------------------

/// A zone outage fails a (tenant, node) pair iff the zone map assigns
/// that pair to the dead zone — both directions, over the whole grid.
#[test]
fn zone_outage_schedules_exactly_the_mapped_nodes() {
    let zones = ZoneMap::new(3, 0x20ED);
    let faults = zones.zone_outage(24, 4, 2, 30);
    for t in 0..24 {
        for n in 0..4 {
            let scheduled = faults.iter().any(|f| f.tenant == t && f.node == n);
            let mapped = zones.zone_of(t, n) == 2;
            assert_eq!(
                scheduled, mapped,
                "tenant {t} node {n}: scheduled={scheduled} mapped={mapped}"
            );
        }
    }
    assert!(faults.iter().all(|f| f.at_tick == 30));
}

// ---------------------------------------------------------------------
// partition model
// ---------------------------------------------------------------------

/// Movement GB never exceeds the flat per-tenant baseline, and equals
/// it exactly when every shard moves (empty destination / no shared
/// hyperedge); a destination sharing every hyperedge moves nothing.
#[test]
fn moved_gb_is_bounded_by_flat_and_tight_when_all_shards_move() {
    let flat = 2.0f64;
    let m = ShardModel::uniform(8, flat, 6, 4, 0xC0DE);
    let mut rng = XorShift64::new(0xD15C);
    for t in 0..8 {
        assert!((m.total_gb(t) - flat).abs() < 1e-9);
        // empty destination: everything moves — moved == flat exactly
        assert_eq!(m.moved_gb(t, &[]), m.total_gb(t));
        // arbitrary resident sets never push moved above flat
        for _ in 0..50 {
            let residents: Vec<usize> = (0..8).filter(|_| rng.next_f64() < 0.5).collect();
            let moved = m.moved_gb(t, &residents);
            assert!(moved <= m.total_gb(t) + 1e-12, "tenant {t} moved {moved} over flat {flat}");
        }
    }
    // a single shared hyperedge: any occupied destination already
    // carries every edge, so a disjoint-shard move prices zero
    let one = ShardModel::uniform(4, flat, 6, 1, 0xC0DE);
    assert_eq!(one.moved_gb(0, &[1]), 0.0);
}

/// The sim-level pin: a packed placement run priced through a shard
/// map ships no more data than `migrations × tenant_gb`, and strictly
/// less once any migration lands on an occupied destination (which
/// consolidation guarantees); the default-off flat path still prices
/// exactly `migrations × tenant_gb` per move — the PR-4 baseline —
/// and stays deterministic.
#[test]
fn partition_aware_pricing_ships_less_data_than_the_flat_baseline() {
    let cfg = ModelConfig::default_paper();
    let pcfg = PlacementConfig::default();
    let steps = 20;

    // flat baseline (default off): every migration ships tenant_gb
    let mut flat = PlacementSim::packed(&cfg, constant_tenant_specs(&cfg, 12), 1.0e6, 3, pcfg);
    let fres = flat.run(steps);
    let fmig = fres.total_migrations();
    assert!(fmig > 0, "consolidation never migrated");
    assert!(
        (flat.total_moved_gb() - fmig as f64 * pcfg.tenant_gb).abs() < 1e-6,
        "flat pricing must ship exactly migrations × tenant_gb: {} vs {}",
        flat.total_moved_gb(),
        fmig as f64 * pcfg.tenant_gb
    );

    // one shared hyperedge: a move onto any occupied destination is
    // fully discounted, so consolidation must ship strictly less
    let mut shard = PlacementSim::packed(&cfg, constant_tenant_specs(&cfg, 12), 1.0e6, 3, pcfg);
    shard.set_shard_model(ShardModel::uniform(12, pcfg.tenant_gb, 6, 1, 0x5EED));
    let sres = shard.run(steps);
    let smig = sres.total_migrations();
    assert!(smig > 0, "shard-priced run never migrated");
    assert!(
        shard.total_moved_gb() < smig as f64 * pcfg.tenant_gb,
        "partition-aware pricing never discounted a move: {} GB over {} migrations",
        shard.total_moved_gb(),
        smig
    );

    // PR-4 guard: the default-off path is deterministic tick for tick
    let mut again = PlacementSim::packed(&cfg, constant_tenant_specs(&cfg, 12), 1.0e6, 3, pcfg);
    let ares = again.run(steps);
    assert_eq!(fres.ticks, ares.ticks);
    assert_eq!(again.total_moved_gb(), flat.total_moved_gb());
}

// ---------------------------------------------------------------------
// preset comparison pins (one per preset; see CONTRIBUTING.md)
// ---------------------------------------------------------------------

fn run_flat(cfg: &ModelConfig, specs: Vec<TenantSpec>, budget: f32, steps: usize) -> FleetResult {
    FleetSimulator::with_arbiter(cfg, specs, BudgetArbiter::flat(budget, 3)).run(steps)
}

fn run_planning(
    cfg: &ModelConfig,
    specs: Vec<TenantSpec>,
    budget: f32,
    steps: usize,
) -> FleetResult {
    let arb = BudgetArbiter::new(budget, 3).with_envelopes(ClassEnvelopes::default_split());
    let mut fleet = FleetSimulator::with_arbiter(cfg, specs, arb);
    fleet.enable_forecasts(ForecastKind::Seasonal, 3);
    fleet.run(steps)
}

/// The crowd presets' planning-vs-flat pin. The correlated spike
/// contends the shared budget; the flat arbiter can only deny there
/// (it structurally never degrades or re-negotiates — `admit_flat` has
/// no candidate walk and no shed pass), while the planning arbiter
/// converts the same contention into lower-ranked admissions and shed
/// funding. Both arms stay within budget and deterministic.
fn crowd_preset_pin(name: &str) {
    let cfg = ModelConfig::default_paper();
    let budget = 8.0f32; // the pinned contended 6-tenant budget
    let sc = scenario::preset(name, &cfg, 6, scenario::DEFAULT_SEED).unwrap();
    assert!(sc.faults.is_empty(), "{name} is a pure workload preset");

    let flat = run_flat(&cfg, sc.specs.clone(), budget, sc.steps);
    let plan = run_planning(&cfg, sc.specs.clone(), budget, sc.steps);
    assert!(flat.within_budget(budget), "{name}: flat peak {}", flat.peak_spend());
    assert!(plan.within_budget(budget), "{name}: plan peak {}", plan.peak_spend());

    let flat_denied: usize = flat.ticks.iter().map(|t| t.denied_moves).sum();
    assert!(flat_denied > 0, "{name}: the correlated spike never contended the budget");
    assert_eq!(
        flat.ticks.iter().map(|t| t.degraded_moves + t.shed_moves).sum::<usize>(),
        0,
        "{name}: the flat baseline must only deny"
    );
    let engaged: usize = plan.ticks.iter().map(|t| t.degraded_moves + t.shed_moves).sum();
    assert!(engaged > 0, "{name}: planning never engaged the candidate walk");

    let again = run_planning(&cfg, sc.specs.clone(), budget, sc.steps);
    assert_eq!(plan.ticks, again.ticks, "{name}: planning run drifted");
}

#[test]
fn flash_crowd_planning_beats_flat_denial() {
    crowd_preset_pin("flash-crowd");
}

#[test]
fn black_friday_planning_beats_flat_denial() {
    crowd_preset_pin("black-friday");
}

/// The fault presets' pin, two halves. (1) Planning-vs-flat on the
/// preset fleet: these specs are exactly the pinned contended 6-tenant
/// shape (phase-shifted paper traces, classes cycling G/S/B), where
/// budget-aware planning strictly beats flat denial on violation ticks
/// — the PR-3 acceptance margin (~196 vs ~244). (2) The preset's fault
/// schedule lands on DES substrates: every event is accepted through
/// `schedule_node_failure`, and the faulted run is deterministic tick
/// for tick.
fn fault_preset_pin(name: &str) {
    let cfg = ModelConfig::default_paper();
    let budget = 8.0f32;
    let sc = scenario::preset(name, &cfg, 6, scenario::DEFAULT_SEED).unwrap();
    assert!(!sc.faults.is_empty(), "{name} must carry a fault schedule");

    let flat = run_flat(&cfg, sc.specs.clone(), budget, 100);
    let plan = run_planning(&cfg, sc.specs.clone(), budget, 100);
    assert!(flat.within_budget(budget) && plan.within_budget(budget));
    assert!(
        plan.total_violations() < flat.total_violations(),
        "{name}: planning must strictly beat flat denial: {} vs {}",
        plan.total_violations(),
        flat.total_violations()
    );

    let faulted = || {
        let mut fleet = FleetSimulator::new(&cfg, sc.specs.clone(), budget, 3);
        fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
        let accepted = fleet.schedule_faults(&sc.faults, ClusterParams::default().interval);
        assert_eq!(accepted, sc.faults.len(), "{name}: a fault event was rejected");
        fleet.set_scenario(sc.name, accepted);
        fleet.run(sc.steps)
    };
    let a = faulted();
    let b = faulted();
    assert_eq!(a.ticks, b.ticks, "{name}: faulted DES run drifted");
}

#[test]
fn zone_outage_planning_beats_flat_denial() {
    fault_preset_pin("zone-outage");
}

#[test]
fn failure_storm_planning_beats_flat_denial() {
    fault_preset_pin("failure-storm");
}

#[test]
fn rolling_restart_planning_beats_flat_denial() {
    fault_preset_pin("rolling-restart");
}

/// The heavy-tail preset's packed-vs-dedicated pin: with Pareto-sized
/// tenants (most tiny, a few huge) shared-host packing must cost
/// strictly less than one-cluster-per-tenant, with real consolidation
/// migrations, while the dedicated baseline never migrates (and so
/// never ships a byte). Deterministic end to end.
#[test]
fn heavy_tail_packed_beats_dedicated() {
    let cfg = ModelConfig::default_paper();
    let sc = scenario::preset("heavy-tail", &cfg, 12, scenario::DEFAULT_SEED).unwrap();
    let shards = sc.shards.clone().expect("heavy-tail ships a shard-affinity map");
    assert_eq!(shards.n_tenants(), 12);
    let pcfg = PlacementConfig::default();
    let steps = 40;

    let mut ded = PlacementSim::dedicated(&cfg, sc.specs.clone(), 1.0e6, 3, pcfg);
    let dres = ded.run(steps);
    assert_eq!(dres.total_migrations(), 0, "dedicated baseline must not migrate");
    assert_eq!(ded.total_moved_gb(), 0.0);

    let build = || {
        let mut p = PlacementSim::packed(&cfg, sc.specs.clone(), 1.0e6, 3, pcfg);
        p.set_shard_model(shards.clone());
        p
    };
    let mut packed = build();
    let pres = packed.run(steps);
    assert!(
        pres.total_cost() < dres.total_cost(),
        "packing the heavy tail must be strictly cheaper: {} vs {}",
        pres.total_cost(),
        dres.total_cost()
    );
    assert!(pres.total_migrations() > 0, "consolidation never migrated");

    let again = build().run(steps);
    assert_eq!(pres.ticks, again.ticks, "heavy-tail packed run drifted");
}
