//! Property tests pinning the activity-proportional control plane to
//! the always-replan reference: a dirty-queue fleet must be
//! **decision-identical** — same verdict counts, same spend trajectory
//! (bitwise), same final configurations — across every scenario shape
//! (idle fleets, wake storms, node failures, adaptive envelopes,
//! sparse-activity mixes), while actually caching where the scenario
//! guarantees cacheable holds. The indexed (heap-based) admission is
//! differentially tested against the pre-index global-sort passes over
//! random proposal batches, the [`SpendLedger`] fold against the
//! per-tick spend walk, and the f64 spend accumulation against
//! 10k-tenant catastrophic f32 drift.

use diagonal_scale::cluster::{ClusterParams, SubstrateKind};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    Admission, BudgetArbiter, Candidate, ClassEnvelopes, FleetSimulator, PriorityClass, Proposal,
    SpendLedger, TenantSpec,
};
use diagonal_scale::placement::{small_tenant_specs, PlacementConfig, PlacementSim};
use diagonal_scale::plane::Configuration;
use diagonal_scale::serverless::{
    mostly_idle_specs, sparse_activity_specs, wake_storm_specs, ServerlessParams,
};
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::{TraceBuilder, XorShift64};

// ---------------------------------------------------------------------
// dirty queue vs always-replan: decision identity per scenario shape
// ---------------------------------------------------------------------

/// Tick two identically-built fleets side by side — one with the dirty
/// queue on (the default), one forced to re-propose every tenant every
/// tick — and require identical tick timelines (FleetTick equality
/// covers verdict counts and the bitwise spend trajectory; it excludes
/// `fresh_proposals`/`planning_micros` by design), identical final
/// configurations, and identical fairness bookkeeping. When
/// `require_caching` the scenario guarantees cacheable holds, so the
/// dirty fleet must have skipped a strict majority of nothing — just
/// strictly fewer fresh proposals than the reference.
fn assert_decision_identical(
    mut dirty: FleetSimulator,
    mut full: FleetSimulator,
    steps: usize,
    require_caching: bool,
    label: &str,
) {
    dirty.set_dirty_planning(true);
    full.set_dirty_planning(false);
    let (mut dirty_fresh, mut full_fresh) = (0usize, 0usize);
    for s in 0..steps {
        let a = dirty.tick();
        let b = full.tick();
        assert_eq!(a, b, "{label}: tick {s} diverged (dirty {a:?} vs full {b:?})");
        dirty_fresh += a.fresh_proposals;
        full_fresh += b.fresh_proposals;
    }
    assert_eq!(
        dirty.spend().to_bits(),
        full.spend().to_bits(),
        "{label}: final spend diverged bitwise"
    );
    for (d, f) in dirty.tenants().iter().zip(full.tenants()) {
        assert_eq!(d.current(), f.current(), "{label}: tenant {} config diverged", d.name());
        assert_eq!(
            d.max_denial_streak,
            f.max_denial_streak,
            "{label}: tenant {} streak diverged",
            d.name()
        );
        assert_eq!(
            d.rescue_unaffordable_total,
            f.rescue_unaffordable_total,
            "{label}: tenant {} rescue accounting diverged",
            d.name()
        );
    }
    assert_eq!(full_fresh, full.tenants().len() * steps, "{label}: reference fleet cached");
    if require_caching {
        assert!(
            dirty_fresh < full_fresh,
            "{label}: dirty queue never cached ({dirty_fresh} fresh of {full_fresh})"
        );
    }
}

#[test]
fn idle_serverless_fleet_is_decision_identical_under_dirty_planning() {
    let cfg = ModelConfig::default_paper();
    let build = || {
        let mut fleet =
            FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 24, 0.75), 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        fleet
    };
    assert_decision_identical(build(), build(), 120, true, "mostly-idle");
}

#[test]
fn wake_storm_is_decision_identical_under_dirty_planning() {
    let cfg = ModelConfig::default_paper();
    let build = || {
        let mut fleet =
            FleetSimulator::new(&cfg, wake_storm_specs(&cfg, 24, 0.8, 25, 4), 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        fleet
    };
    assert_decision_identical(build(), build(), 120, true, "wake-storm");
}

#[test]
fn node_failure_is_decision_identical_under_dirty_planning() {
    // event-backed tenants on a steady trace; a node failure mid-run
    // flips measured SLA state, which must dirty the victim out of its
    // cached hold on both fleets in the same tick
    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::from_config(&cfg);
    let build = || {
        let specs: Vec<TenantSpec> = (0..6)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{i}"),
                    match i % 3 {
                        0 => PriorityClass::Gold,
                        1 => PriorityClass::Silver,
                        _ => PriorityClass::Bronze,
                    },
                    base.constant(8.0, 60),
                )
            })
            .collect();
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
        // mid-interval at tick 10, on the victim's substrate time scale
        let at = 10.5 * ClusterParams::default().interval;
        assert!(fleet.tenants_mut()[0].schedule_node_failure(at, 0), "failure not scheduled");
        fleet
    };
    assert_decision_identical(build(), build(), 40, false, "node-failure");
}

#[test]
fn adaptive_envelopes_are_decision_identical_under_dirty_planning() {
    // contended budget + per-tick envelope re-weighting: budget hints
    // move every tick, exercising the hint arm of the invalidation set
    let cfg = ModelConfig::default_paper();
    let base = TraceBuilder::paper(&cfg);
    let build = || {
        let specs: Vec<TenantSpec> = (0..8)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{i}"),
                    match i % 3 {
                        0 => PriorityClass::Gold,
                        1 => PriorityClass::Silver,
                        _ => PriorityClass::Bronze,
                    },
                    base.shifted(i * base.len() / 8),
                )
            })
            .collect();
        let arb = BudgetArbiter::new(8.0 * 1.5, 3).with_envelopes(ClassEnvelopes::default_split());
        let mut fleet = FleetSimulator::with_arbiter(&cfg, specs, arb);
        fleet.enable_adaptive_envelopes();
        fleet
    };
    assert_decision_identical(build(), build(), 100, false, "adaptive-envelopes");
}

#[test]
fn sparse_activity_mixed_substrates_are_decision_identical_under_dirty_planning() {
    // the 10k-bench scenario at test scale: a small DES-backed active
    // cohort over an analytical idle sea, serverless parking the rest
    let cfg = ModelConfig::default_paper();
    let build = || {
        let mut fleet =
            FleetSimulator::new(&cfg, sparse_activity_specs(&cfg, 64, 8, 4), 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        fleet.attach_mixed_substrates(&cfg, ClusterParams::default(), 42, |id| {
            if id < 8 {
                SubstrateKind::Des
            } else {
                SubstrateKind::Analytical
            }
        });
        fleet
    };
    assert_decision_identical(build(), build(), 120, true, "sparse-activity");
}

#[test]
fn random_fleets_are_decision_identical_under_dirty_planning() {
    // randomized shapes: class mix, trace phases, budget tightness —
    // tight budgets keep denial streaks churning through the
    // invalidation set
    let cfg = ModelConfig::default_paper();
    forall(8, 0xD127, |case, rng| {
        let n = 2 + rng.below(8) as usize;
        let base = TraceBuilder::paper(&cfg);
        let specs: Vec<TenantSpec> = (0..n)
            .map(|i| {
                TenantSpec::from_config(
                    &cfg,
                    format!("t{case}-{i}"),
                    match rng.below(3) {
                        0 => PriorityClass::Gold,
                        1 => PriorityClass::Silver,
                        _ => PriorityClass::Bronze,
                    },
                    base.shifted(rng.below(50) as usize),
                )
            })
            .collect();
        let budget = n as f32 * uniform(rng, 0.6, 3.0);
        let envelopes = rng.next_f64() < 0.5;
        let build = || {
            let arb = if envelopes {
                BudgetArbiter::new(budget, 3).with_envelopes(ClassEnvelopes::default_split())
            } else {
                BudgetArbiter::new(budget, 3)
            };
            FleetSimulator::with_arbiter(&cfg, specs.clone(), arb)
        };
        assert_decision_identical(build(), build(), 60, false, &format!("random case {case}"));
    });
}

#[test]
fn refresh_k_safety_net_forces_refreshes_without_changing_decisions() {
    // a tiny mandatory-refresh interval re-proposes cached holds
    // constantly; decisions must not move, only the planning work
    let cfg = ModelConfig::default_paper();
    let build = || {
        let mut fleet =
            FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 24, 0.75), 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        fleet
    };
    let mut k2 = build();
    k2.set_refresh_k(2);
    let mut k_default = build();
    let mut full = build();
    full.set_dirty_planning(false);
    let (mut fresh_k2, mut fresh_default, mut fresh_full) = (0usize, 0usize, 0usize);
    for s in 0..60 {
        let a = k2.tick();
        let b = k_default.tick();
        let c = full.tick();
        assert_eq!(a, b, "refresh-k: tick {s} diverged from default-K fleet");
        assert_eq!(a, c, "refresh-k: tick {s} diverged from always-replan fleet");
        fresh_k2 += a.fresh_proposals;
        fresh_default += b.fresh_proposals;
        fresh_full += c.fresh_proposals;
    }
    assert!(
        fresh_default < fresh_k2 && fresh_k2 < fresh_full,
        "refresh pressure should order planning work: \
         default {fresh_default} < K=2 {fresh_k2} < full {fresh_full}"
    );
}

// ---------------------------------------------------------------------
// indexed admission vs the sorted reference implementation
// ---------------------------------------------------------------------

fn rand_class(rng: &mut XorShift64) -> PriorityClass {
    match rng.below(3) {
        0 => PriorityClass::Gold,
        1 => PriorityClass::Silver,
        _ => PriorityClass::Bronze,
    }
}

fn rand_config(rng: &mut XorShift64) -> Configuration {
    Configuration::new(rng.below(4) as usize, rng.below(4) as usize)
}

/// Same self-consistent random proposal shape as `prop_fleet.rs`: a
/// hold (possibly with shed offers) or a ranked candidate list whose
/// alternatives get strictly cheaper down the list.
fn rand_proposal(rng: &mut XorShift64, tenant: usize) -> Proposal {
    let from = rand_config(rng);
    let cost_from = uniform(rng, 0.08, 8.0);
    let hold = rng.next_f64() < 0.25;
    let mut candidates = Vec::new();
    if !hold {
        let n_cands = 1 + rng.below(3) as usize;
        let mut cost = uniform(rng, 0.08, 8.0);
        for _ in 0..n_cands {
            candidates.push(Candidate::priced(rand_config(rng), cost, uniform(rng, 0.0, 50.0)));
            cost *= uniform(rng, 0.3, 0.95);
        }
    }
    let sla_violating = rng.next_f64() < 0.3;
    let emergency = !hold && rng.next_f64() < 0.1;
    let mut sheds = Vec::new();
    if hold && !sla_violating && rng.next_f64() < 0.6 {
        sheds.push(Candidate::priced(
            rand_config(rng),
            cost_from * uniform(rng, 0.3, 0.95),
            uniform(rng, 0.0, 5.0),
        ));
    }
    Proposal {
        tenant,
        class: rand_class(rng),
        from,
        cost_from,
        current_score: 0.0,
        emergency,
        sla_violating,
        denial_streak: rng.below(6) as usize,
        fallback: false,
        candidates,
        sheds,
    }
}

fn assert_admissions_identical(a: &Admission, b: &Admission, label: &str) {
    assert_eq!(a.verdicts, b.verdicts, "{label}: verdicts diverged");
    assert_eq!(a.chosen, b.chosen, "{label}: chosen options diverged");
    assert_eq!(
        a.base_spend.to_bits(),
        b.base_spend.to_bits(),
        "{label}: base spend diverged bitwise"
    );
    assert_eq!(
        a.projected_spend.to_bits(),
        b.projected_spend.to_bits(),
        "{label}: projected spend diverged bitwise"
    );
}

#[test]
fn indexed_admission_matches_the_sorted_reference() {
    forall(400, 0x1DE7ED, |_, rng| {
        let n = 1 + rng.below(32) as usize;
        let proposals: Vec<Proposal> = (0..n).map(|i| rand_proposal(rng, i)).collect();
        let base: f32 = proposals.iter().map(|p| p.cost_from).sum();
        // budgets from under-water (forced sheds/denials everywhere) to
        // comfortable, with and without class envelopes
        let budget = base * uniform(rng, 0.8, 1.6) + 0.01;
        let env = ClassEnvelopes::new(
            uniform(rng, 0.1, 1.0),
            uniform(rng, 0.1, 1.0),
            uniform(rng, 0.1, 1.0),
        );
        for arb in
            [BudgetArbiter::new(budget, 3), BudgetArbiter::new(budget, 3).with_envelopes(env)]
        {
            let indexed = arb.admit(&proposals);
            let sorted = arb.sorted_reference().admit(&proposals);
            assert_admissions_identical(&indexed, &sorted, "indexed vs sorted");
        }
    });
}

#[test]
fn placement_backed_decisions_match_the_sorted_reference() {
    // the placement control loop routes every packed action through
    // `BudgetArbiter::admit` — the indexed heaps must not change a
    // single placement decision vs the global-sort reference, under
    // contention and with money to spare
    let cfg = ModelConfig::default_paper();
    let pcfg = PlacementConfig::default();
    for budget in [6.0f32, 1.0e6] {
        let build = |arb: BudgetArbiter| {
            PlacementSim::new(
                &cfg,
                small_tenant_specs(&cfg, 12, 0.1),
                arb,
                ClusterParams::default(),
                pcfg,
                true,
            )
        };
        let mut indexed = build(BudgetArbiter::new(budget, 3));
        let mut sorted = build(BudgetArbiter::new(budget, 3).sorted_reference());
        for s in 0..60 {
            let a = indexed.tick();
            let b = sorted.tick();
            assert_eq!(a, b, "placement tick {s} diverged at budget {budget}");
        }
        assert_eq!(
            indexed.spend().to_bits(),
            sorted.spend().to_bits(),
            "placement spend diverged bitwise at budget {budget}"
        );
        assert_eq!(indexed.clusters().len(), sorted.clusters().len());
    }
}

#[test]
fn ledgered_admission_matches_the_spend_walk() {
    forall(200, 0x1ED9E2, |_, rng| {
        let n = 1 + rng.below(24) as usize;
        let proposals: Vec<Proposal> = (0..n).map(|i| rand_proposal(rng, i)).collect();
        let mut ledger = SpendLedger::new();
        for (i, p) in proposals.iter().enumerate() {
            ledger.record(i, p.cost_from, p.class);
        }
        let base: f32 = proposals.iter().map(|p| p.cost_from).sum();
        let budget = base * uniform(rng, 0.9, 1.5) + 0.01;
        let arb = BudgetArbiter::new(budget, 3).with_envelopes(ClassEnvelopes::default_split());
        let walked = arb.admit(&proposals);
        let ledgered = arb.admit_ledgered(&proposals, &ledger);
        assert_admissions_identical(&walked, &ledgered, "walked vs ledgered");
    });
}

// ---------------------------------------------------------------------
// f64 spend accumulation: 10k tiny costs must not drift
// ---------------------------------------------------------------------

#[test]
fn spend_accumulation_survives_ten_thousand_tiny_costs() {
    // 10_000 storage-only holds at 0.008/h: the exact sum is
    // n * (0.008 as f32 as f64). A running f32 sum drifts ~3.3e-3 here
    // (systematic rounding at magnitudes near 80 — already past the
    // fleet's 1e-3 budget epsilon, and it grows linearly with fleet
    // size); the arbiter's f64 walk narrows once, within ~4e-6.
    let n = 10_000usize;
    let cost = 0.008f32;
    let proposals: Vec<Proposal> = (0..n)
        .map(|i| Proposal {
            tenant: i,
            class: PriorityClass::Bronze,
            from: Configuration::new(0, 0),
            cost_from: cost,
            current_score: 0.0,
            emergency: false,
            sla_violating: false,
            denial_streak: 0,
            fallback: false,
            candidates: Vec::new(),
            sheds: Vec::new(),
        })
        .collect();
    let exact = n as f64 * cost as f64;
    let naive: f32 = proposals.iter().map(|p| p.cost_from).sum();
    assert!(
        (naive as f64 - exact).abs() > 1e-3,
        "f32 drift vanished ({naive} vs {exact}) — this regression guard lost its teeth"
    );
    for arb in [BudgetArbiter::new(100.0, 3), BudgetArbiter::flat(100.0, 3)] {
        let adm = arb.admit(&proposals);
        assert!(
            (adm.base_spend as f64 - exact).abs() < 1e-3,
            "base spend {} drifted from exact {exact}",
            adm.base_spend
        );
        assert!(
            (adm.projected_spend as f64 - exact).abs() < 1e-3,
            "projected spend {} drifted from exact {exact}",
            adm.projected_spend
        );
    }
}
