//! Property pins for the HyperLogLog cardinality sketch: the relative
//! error stays inside the classical 3σ bound (σ = 1.04/√m) across
//! seeded cardinalities from 10 to 100k, merge is exactly the union
//! sketch, and duplicates never grow the estimate. Deterministic — the
//! streams come from the repo's seeded `XorShift64`, so the observed
//! errors are the same on every run (worst case over this grid is
//! ≈ 0.059 at the default precision, against a bound of 0.0975).

use diagonal_scale::metrics::hll::{Hll, DEFAULT_PRECISION};
use diagonal_scale::workload::XorShift64;

#[test]
fn relative_error_stays_inside_three_sigma() {
    // 3σ with σ = 1.04/√m and m = 2^DEFAULT_PRECISION = 1024
    let bound = 3.0 * 1.04 / ((1u64 << DEFAULT_PRECISION) as f64).sqrt();
    assert!((bound - 0.0975).abs() < 1e-4, "bound sanity: {bound}");
    for seed in [1u64, 42, 2026] {
        for n in [10usize, 100, 1_000, 10_000, 100_000] {
            let mut rng = XorShift64::new(seed);
            let mut sketch = Hll::default();
            for _ in 0..n {
                sketch.insert_u64(rng.next_u64());
            }
            let est = sketch.estimate();
            let rel = (est - n as f64).abs() / n as f64;
            assert!(
                rel <= bound,
                "seed {seed}, n {n}: estimate {est:.1}, relative error {rel:.4} > {bound:.4}"
            );
        }
    }
}

#[test]
fn merge_equals_the_union_sketch_exactly() {
    for seed in [3u64, 9, 77] {
        let mut rng_a = XorShift64::new(seed);
        let mut rng_b = XorShift64::new(seed ^ 0xFFFF_0000);
        let mut a = Hll::default();
        let mut b = Hll::default();
        let mut union = Hll::default();
        for _ in 0..20_000 {
            let x = rng_a.next_u64();
            let y = rng_b.next_u64();
            a.insert_u64(x);
            union.insert_u64(x);
            b.insert_u64(y);
            union.insert_u64(y);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, union, "register-wise max must equal the union sketch");
        assert_eq!(merged.estimate().to_bits(), union.estimate().to_bits());
    }
}

#[test]
fn duplicates_never_grow_the_estimate() {
    let mut sketch = Hll::default();
    let mut rng = XorShift64::new(11);
    let distinct: Vec<u64> = (0..500).map(|_| rng.next_u64()).collect();
    for &v in &distinct {
        sketch.insert_u64(v);
    }
    let once = sketch.estimate();
    for _ in 0..20 {
        for &v in &distinct {
            sketch.insert_u64(v);
        }
    }
    assert_eq!(sketch.estimate().to_bits(), once.to_bits(), "re-inserts must be no-ops");
    let rel = (once - 500.0).abs() / 500.0;
    assert!(rel < 0.0975, "500 distinct estimated at {once:.1}");
}

#[test]
fn memory_is_m_registers_regardless_of_stream_length() {
    // the sketch is dense: m = 2^p one-byte registers, fixed at
    // construction — the whole point of counting distinct tenants
    // without holding tenant sets
    let sketch = Hll::default();
    assert_eq!(sketch.m(), 1 << DEFAULT_PRECISION);
    assert!((sketch.standard_error() - 1.04 / (sketch.m() as f64).sqrt()).abs() < 1e-12);
}
