//! Property pins for the HyperLogLog cardinality sketch: the relative
//! error stays inside the classical 3σ bound (σ = 1.04/√m) across
//! seeded cardinalities from 10 to 100k, merge is exactly the union
//! sketch, and duplicates never grow the estimate. Deterministic — the
//! streams come from the repo's seeded `XorShift64`, so the observed
//! errors are the same on every run (worst case over this grid is
//! ≈ 0.059 at the default precision, against a bound of 0.0975).

use diagonal_scale::metrics::hll::{Hll, HllWindowRing, DEFAULT_PRECISION};
use diagonal_scale::workload::XorShift64;

#[test]
fn relative_error_stays_inside_three_sigma() {
    // 3σ with σ = 1.04/√m and m = 2^DEFAULT_PRECISION = 1024
    let bound = 3.0 * 1.04 / ((1u64 << DEFAULT_PRECISION) as f64).sqrt();
    assert!((bound - 0.0975).abs() < 1e-4, "bound sanity: {bound}");
    for seed in [1u64, 42, 2026] {
        for n in [10usize, 100, 1_000, 10_000, 100_000] {
            let mut rng = XorShift64::new(seed);
            let mut sketch = Hll::default();
            for _ in 0..n {
                sketch.insert_u64(rng.next_u64());
            }
            let est = sketch.estimate();
            let rel = (est - n as f64).abs() / n as f64;
            assert!(
                rel <= bound,
                "seed {seed}, n {n}: estimate {est:.1}, relative error {rel:.4} > {bound:.4}"
            );
        }
    }
}

#[test]
fn merge_equals_the_union_sketch_exactly() {
    for seed in [3u64, 9, 77] {
        let mut rng_a = XorShift64::new(seed);
        let mut rng_b = XorShift64::new(seed ^ 0xFFFF_0000);
        let mut a = Hll::default();
        let mut b = Hll::default();
        let mut union = Hll::default();
        for _ in 0..20_000 {
            let x = rng_a.next_u64();
            let y = rng_b.next_u64();
            a.insert_u64(x);
            union.insert_u64(x);
            b.insert_u64(y);
            union.insert_u64(y);
        }
        let mut merged = a.clone();
        merged.merge(&b);
        assert_eq!(merged, union, "register-wise max must equal the union sketch");
        assert_eq!(merged.estimate().to_bits(), union.estimate().to_bits());
    }
}

#[test]
fn duplicates_never_grow_the_estimate() {
    let mut sketch = Hll::default();
    let mut rng = XorShift64::new(11);
    let distinct: Vec<u64> = (0..500).map(|_| rng.next_u64()).collect();
    for &v in &distinct {
        sketch.insert_u64(v);
    }
    let once = sketch.estimate();
    for _ in 0..20 {
        for &v in &distinct {
            sketch.insert_u64(v);
        }
    }
    assert_eq!(sketch.estimate().to_bits(), once.to_bits(), "re-inserts must be no-ops");
    let rel = (once - 500.0).abs() / 500.0;
    assert!(rel < 0.0975, "500 distinct estimated at {once:.1}");
}

/// Feed `per_window` fresh draws into the ring, rotate, and return the
/// exact window streams so expectations can be rebuilt independently.
fn feed_windows(
    ring: &mut HllWindowRing,
    windows: usize,
    per_window: usize,
    seed: u64,
) -> Vec<Vec<u64>> {
    let mut rng = XorShift64::new(seed);
    let mut streams = Vec::with_capacity(windows);
    for _ in 0..windows {
        let stream: Vec<u64> = (0..per_window).map(|_| rng.next_u64()).collect();
        for &v in &stream {
            ring.insert_u64(v);
        }
        ring.rotate();
        streams.push(stream);
    }
    streams
}

#[test]
fn ring_retains_exactly_the_last_cap_windows_and_evicts_oldest_first() {
    let cap = 4;
    let mut ring = HllWindowRing::new(cap, DEFAULT_PRECISION);
    assert_eq!(ring.capacity(), cap);
    let streams = feed_windows(&mut ring, cap + 3, 300, 0x81F6);
    assert_eq!(ring.rotations(), (cap + 3) as u64);
    assert_eq!(ring.closed_windows().len(), cap, "ring must stay bounded at cap");
    // the retained windows are exactly the last `cap`, oldest first —
    // rebuild each expected sketch from the recorded stream and compare
    // register-for-register (Hll is PartialEq)
    for (i, stream) in streams[streams.len() - cap..].iter().enumerate() {
        let mut expect = Hll::new(DEFAULT_PRECISION);
        for &v in stream {
            expect.insert_u64(v);
        }
        assert_eq!(
            ring.closed_windows()[i], expect,
            "retained window {i} is not the expected (non-evicted) sketch"
        );
    }
}

#[test]
fn rotate_returns_the_closed_windows_estimate_and_clears_the_open_one() {
    let mut ring = HllWindowRing::new(3, DEFAULT_PRECISION);
    let mut rng = XorShift64::new(0x0417);
    assert!(ring.open_is_empty());
    for _ in 0..1_000 {
        ring.insert_u64(rng.next_u64());
    }
    let before = ring.open_estimate();
    let closed = ring.rotate();
    assert_eq!(closed.to_bits(), before.to_bits(), "rotate must return the closed estimate");
    assert!(ring.open_is_empty(), "rotation must start a fresh open window");
    assert_eq!(ring.open_estimate(), 0.0);
    // an empty rotation is legal and pushes an empty window
    assert_eq!(ring.rotate(), 0.0);
    assert_eq!(ring.closed_windows().len(), 2);
}

#[test]
fn merged_estimate_equals_the_union_sketch_bitwise() {
    let cap = 5;
    let mut ring = HllWindowRing::new(cap, DEFAULT_PRECISION);
    // overflow the ring so the merge runs over a full ring, not a
    // partially filled one
    let streams = feed_windows(&mut ring, cap + 2, 400, 0xB10C);
    let mut union = Hll::new(DEFAULT_PRECISION);
    for stream in &streams[streams.len() - cap..] {
        for &v in stream {
            union.insert_u64(v);
        }
    }
    assert_eq!(
        ring.merged_estimate().to_bits(),
        union.estimate().to_bits(),
        "lookback gauge must equal one sketch fed all retained streams"
    );
    // an empty ring reports zero actives, not NaN
    let empty = HllWindowRing::new(cap, DEFAULT_PRECISION);
    assert_eq!(empty.merged_estimate(), 0.0);
}

#[test]
fn memory_is_m_registers_regardless_of_stream_length() {
    // the sketch is dense: m = 2^p one-byte registers, fixed at
    // construction — the whole point of counting distinct tenants
    // without holding tenant sets
    let sketch = Hll::default();
    assert_eq!(sketch.m(), 1 << DEFAULT_PRECISION);
    assert!((sketch.standard_error() - 1.04 / (sketch.m() as f64).sqrt()).abs() < 1e-12);
}
