//! Golden schema test for `diagonal-scale/explain-v1`: renders a real
//! cluster explain dump and a real fleet explain dump (serverless
//! mostly-idle scenario, so lifecycle / cold-start fields appear) and
//! asserts the union of emitted JSON keys equals the checked-in
//! `config/explain_v1.keys` snapshot, byte for byte.
//!
//! This is the runtime complement to simlint's static
//! `s1-explain-additivity` rule (which extracts the same keys from the
//! emitter source): the static rule catches schema drift before the
//! build, this test proves the rendered output actually matches the
//! snapshot. The schema is additive-only — a missing key here means a
//! breaking removal/rename; an extra key means the snapshot must be
//! updated in the same PR.

use std::collections::BTreeSet;

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::FleetSimulator;
use diagonal_scale::report::{explain_json, fleet_explain_json_scenario};
use diagonal_scale::serverless::mostly_idle_specs;
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::workload::TraceBuilder;

/// Extract every `"key":` object-key occurrence from rendered JSON.
/// String *values* are never followed by `:` in this schema, so a
/// quoted identifier directly followed by a colon is an object key.
fn json_keys(json: &str) -> BTreeSet<String> {
    let b = json.as_bytes();
    let mut keys = BTreeSet::new();
    let mut i = 0;
    while i < b.len() {
        if b[i] == b'"' {
            let start = i + 1;
            let mut j = start;
            while j < b.len() && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            if j > start && j + 1 < b.len() && b[j] == b'"' && b[j + 1] == b':' {
                keys.insert(json[start..j].to_string());
                i = j + 2;
                continue;
            }
            i = start;
        } else {
            i += 1;
        }
    }
    keys
}

fn snapshot_keys() -> BTreeSet<String> {
    include_str!("../../config/explain_v1.keys")
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

#[test]
fn rendered_explain_key_set_matches_snapshot() {
    let cfg = ModelConfig::default_paper();

    // cluster side: a fully explained paper-trace run
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let (run, steps) = sim.run_explained(PolicyKind::Diagonal, &trace, 3);
    let cluster_json = explain_json(&run.policy, &steps);

    // fleet side: the serverless mostly-idle scenario exercises the
    // additive lifecycle / resume_end fields (tenants park and wake);
    // rendering through the scenario emitter with a nonzero cap and a
    // preset name stamps the reservoir fields and the scenario stamp
    let specs = mostly_idle_specs(&cfg, 8, 0.75);
    let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
    fleet.enable_serverless(Default::default());
    fleet.enable_explain(3);
    fleet.run(100);
    let log = fleet.explain_log();
    assert!(!log.is_empty(), "scenario produced no explain records");
    let fleet_json = fleet_explain_json_scenario(log, 5, log.len() as u64, Some("flash-crowd"));
    assert!(
        fleet_json.contains("\"lifecycle\":") && fleet_json.contains("\"resume_end\":"),
        "scenario must exercise the serverless explain fields"
    );
    assert!(
        fleet_json.contains("\"scenario\":\"flash-crowd\""),
        "scenario stamp missing from the fleet dump"
    );

    let mut rendered = json_keys(&cluster_json);
    rendered.extend(json_keys(&fleet_json));

    let pinned = snapshot_keys();
    let missing: Vec<&String> = pinned.difference(&rendered).collect();
    let extra: Vec<&String> = rendered.difference(&pinned).collect();
    assert!(
        missing.is_empty(),
        "keys pinned in config/explain_v1.keys but not rendered (breaking \
         removal/rename — explain-v1 is additive-only): {missing:?}"
    );
    assert!(
        extra.is_empty(),
        "rendered keys not pinned in config/explain_v1.keys (update the \
         snapshot in the same PR so the schema change is reviewable): {extra:?}"
    );
}

#[test]
fn key_extraction_sees_conditional_and_nested_keys() {
    // sanity-check the extractor itself on a shape like the emitters':
    // nested objects, arrays, and string values that must not count
    let json = r#"{"schema":"x","steps":[{"from":{"h":1},"verdict":"Admitted","sheds":0}]}"#;
    let keys = json_keys(json);
    let expect: BTreeSet<String> = ["schema", "steps", "from", "h", "verdict", "sheds"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(keys, expect, "string values must not be counted as keys");
}
