//! Integration: the PJRT runtime executing the AOT-compiled Pallas/JAX
//! artifacts must agree with the native rust surfaces — the contract
//! that lets the coordinator plan on either backend.
//!
//! Requires `make artifacts` (the Makefile test target guarantees it).

use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::plane::Configuration;
use diagonal_scale::runtime::{grid_at, Engine, SurfaceEngine};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::TraceBuilder;
use diagonal_scale::GRID;

/// The AOT artifact directory, when populated. Without `make artifacts`
/// (and real XLA/PJRT bindings in place of the offline stub) every test
/// in this file skips with a note rather than failing.
fn artifacts_dir() -> Option<std::path::PathBuf> {
    let p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    p.join("manifest.json").exists().then_some(p)
}

fn engine() -> Option<SurfaceEngine> {
    let dir = artifacts_dir()?;
    let cfg = ModelConfig::default_paper();
    Some(SurfaceEngine::new(Engine::load(dir).unwrap(), &cfg).unwrap())
}

/// Evaluates to the engine, or skips (returns from) the current test.
macro_rules! require_engine {
    () => {
        match engine() {
            Some(eng) => eng,
            None => {
                eprintln!("skipping: artifacts missing — run `make artifacts`");
                return;
            }
        }
    };
}

fn assert_close(a: f32, b: f32, tol: f32, what: &str) {
    let denom = a.abs().max(b.abs()).max(1.0);
    assert!(
        (a - b).abs() / denom <= tol,
        "{what}: native={a} hlo={b}"
    );
}

#[test]
fn abi_check_passes() {
    require_engine!().check_abi().unwrap();
}

#[test]
fn surfaces_hlo_matches_native_on_all_cells() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let eng = require_engine!();
    for lambda in [100.0f32, 6000.0, 10000.0, 16000.0] {
        let grids = eng.surfaces(lambda).unwrap();
        for c in model.plane().iter() {
            let p = model.evaluate(&c, lambda);
            let at = |g: &[f32]| grid_at(g, c.h_idx, c.v_idx);
            assert_close(p.latency, at(&grids.latency), 1e-4, "latency");
            assert_close(p.throughput, at(&grids.throughput), 1e-4, "throughput");
            assert_close(p.cost, at(&grids.cost), 1e-4, "cost");
            assert_close(p.coordination, at(&grids.coordination), 1e-4, "coordination");
            assert_close(p.objective, at(&grids.objective), 1e-3, "objective");
        }
    }
}

#[test]
fn surfaces_hlo_zeroes_padding() {
    let eng = require_engine!();
    let grids = eng.surfaces(10000.0).unwrap();
    for i in 0..GRID {
        for j in 0..GRID {
            if i >= 4 || j >= 4 {
                assert_eq!(grid_at(&grids.latency, i, j), 0.0, "pad ({i},{j})");
                assert_eq!(grid_at(&grids.objective, i, j), 0.0);
            }
        }
    }
}

#[test]
fn queueing_hlo_matches_native_effective_latency() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let eng = require_engine!();
    for lambda in [1000.0f32, 9000.0, 1.0e9] {
        let (l_final, saturated, _) = eng.queueing(lambda).unwrap();
        for c in model.plane().iter() {
            let want = model.effective_latency(&c, lambda);
            assert_close(want, grid_at(&l_final, c.h_idx, c.v_idx), 1e-4, "l_eff");
            let sat = grid_at(&saturated, c.h_idx, c.v_idx) > 0.5;
            let u = lambda / model.throughput(&c);
            assert_eq!(sat, u >= cfg.surfaces.u_max, "sat at {c:?} lambda={lambda}");
        }
    }
}

#[test]
fn neighbor_hlo_matches_native_scoring() {
    use diagonal_scale::policy::{DiagonalScale, PolicyContext};
    use diagonal_scale::sla::SlaSpec;
    use diagonal_scale::workload::WorkloadPoint;

    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    let eng = require_engine!();
    let (rows, cols) = {
        let m = eng.engine().manifest();
        (m.neighbor_rows, m.neighbor_cols)
    };
    let plane = cfg.plane();

    for (h, v, lambda) in [(1, 1, 6000.0f32), (0, 3, 10000.0), (2, 2, 16000.0), (3, 3, 100.0)] {
        let cur = Configuration::new(h, v);
        let cands = plane.neighbors(&cur, true, true);
        let mut batch = vec![0.0f32; rows * cols];
        for (i, c) in cands.iter().enumerate() {
            let t = plane.tier(c);
            let (dh, dv) = cur.index_distance(c);
            batch[i * cols..i * cols + 9].copy_from_slice(&[
                plane.h_value(c) as f32,
                t.cpu,
                t.ram,
                t.bandwidth,
                t.iops_k(),
                t.cost,
                dh as f32,
                dv as f32,
                1.0,
            ]);
        }
        let (scores, feas) = eng
            .neighbor_scores(&batch, lambda, MoveFlags::DIAGONAL)
            .unwrap();
        let ctx = PolicyContext {
            model: &model,
            sla: &sla,
            reb_h: cfg.policy.reb_h,
            reb_v: cfg.policy.reb_v,
            plan_queue: false,
            future: &[],
            budget: None,
        };
        let w = WorkloadPoint::new(lambda, cfg.write_ratio());
        for (i, c) in cands.iter().enumerate() {
            let native = DiagonalScale::score_candidate(&cur, c, w, &ctx);
            let infeasible = native >= diagonal_scale::INFEASIBLE * 0.5;
            assert_eq!(feas[i] > 0.5, !infeasible, "feasibility at {c:?}");
            if !infeasible {
                assert_close(native, scores[i], 1e-3, "score");
            } else {
                assert!(scores[i] >= diagonal_scale::INFEASIBLE * 0.5);
            }
        }
        // padded rows are invalid
        for i in cands.len()..rows {
            assert_eq!(feas[i], 0.0, "padding row {i}");
        }
    }
}

#[test]
fn surfaces_wide_hlo_matches_native_disagg_model() {
    use diagonal_scale::disagg::{wide_grid_arrays, DisaggConfig, DisaggModel, WIDE};

    let cfg = ModelConfig::default_paper();
    let model = DisaggModel::from_config(&cfg);
    let (hs, tiers, mask, combos) = wide_grid_arrays(model.plane());
    let eng = require_engine!();
    for lambda in [1000.0f32, 9600.0, 16000.0] {
        let grids = eng.surfaces_wide(&hs, &tiers, &mask, lambda).unwrap();
        assert_eq!(grids.len(), 5);
        for h in 0..4 {
            for (j, combo) in combos.iter().enumerate() {
                let c = DisaggConfig::new(h, combo.c_idx, combo.m_idx, combo.s_idx);
                let p = model.evaluate(&c, lambda);
                let idx = h * WIDE + j;
                assert_close(p.latency, grids[0][idx], 1e-4, "wide latency");
                assert_close(p.throughput, grids[1][idx], 1e-4, "wide throughput");
                assert_close(p.cost, grids[2][idx], 1e-4, "wide cost");
                assert_close(p.objective, grids[4][idx], 1e-3, "wide objective");
            }
        }
    }
}

#[test]
fn policy_trace_hlo_matches_native_simulator() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let eng = require_engine!();
    let start = (cfg.policy.start[0], cfg.policy.start[1]);

    for (kind, moves) in [
        (PolicyKind::Diagonal, MoveFlags::DIAGONAL),
        (PolicyKind::HorizontalOnly, MoveFlags::HORIZONTAL_ONLY),
        (PolicyKind::VerticalOnly, MoveFlags::VERTICAL_ONLY),
    ] {
        let native = sim.run(kind, &trace);
        let hlo = eng.policy_trace(&trace, moves, start).unwrap();
        assert_eq!(hlo.len(), native.records.len());
        for (t, (n, h)) in native.records.iter().zip(&hlo).enumerate() {
            assert_eq!(
                (n.config.h_idx, n.config.v_idx),
                (h.h_idx, h.v_idx),
                "{kind:?} trajectory diverges at step {t}"
            );
            assert_eq!(n.violation.latency, h.latency_violation, "step {t}");
            assert_eq!(n.violation.throughput, h.throughput_violation, "step {t}");
            assert_close(n.latency, h.latency, 1e-3, "latency");
            assert_close(n.throughput, h.throughput, 1e-3, "throughput");
            assert_close(n.cost, h.cost, 1e-4, "cost");
            assert_close(n.objective, h.objective, 1e-3, "objective");
        }
    }
}

#[test]
fn policy_trace_pads_short_traces() {
    let cfg = ModelConfig::default_paper();
    let eng = require_engine!();
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.constant(60.0, 7);
    let recs = eng
        .policy_trace(&trace, MoveFlags::DIAGONAL, (1, 1))
        .unwrap();
    assert_eq!(recs.len(), 7);
}

#[test]
fn policy_trace_long_traces_use_bigger_artifact() {
    let cfg = ModelConfig::default_paper();
    let eng = require_engine!();
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.sine(60.0, 160.0, 25, 150);
    let recs = eng
        .policy_trace(&trace, MoveFlags::DIAGONAL, (1, 1))
        .unwrap();
    assert_eq!(recs.len(), 150);
}

#[test]
fn policy_trace_rejects_oversized_traces() {
    let cfg = ModelConfig::default_paper();
    let eng = require_engine!();
    let b = TraceBuilder::from_config(&cfg);
    let trace = b.constant(60.0, 100_000);
    assert!(eng.policy_trace(&trace, MoveFlags::DIAGONAL, (1, 1)).is_err());
}

#[test]
fn unknown_entry_point_is_an_error() {
    let eng = require_engine!();
    assert!(eng.engine().execute("nonexistent", &[]).is_err());
}

#[test]
fn wrong_arity_is_an_error() {
    let eng = require_engine!();
    assert!(eng.engine().execute("surfaces", &[]).is_err());
}
