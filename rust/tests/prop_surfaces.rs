//! Property tests on the analytical surfaces (paper §III): sign,
//! monotonicity, and consistency invariants over randomized tier
//! tables and workloads.

use diagonal_scale::config::ModelConfig;
use diagonal_scale::plane::{Configuration, ScalingPlane, Tier};
use diagonal_scale::sla::SlaSpec;
use diagonal_scale::surfaces::{queueing, SurfaceModel};
use diagonal_scale::testkit::{forall, uniform};
use diagonal_scale::workload::XorShift64;

fn random_tier(rng: &mut XorShift64, name: &str) -> Tier {
    Tier {
        name: name.to_string(),
        cpu: uniform(rng, 0.5, 64.0),
        ram: uniform(rng, 0.5, 128.0),
        bandwidth: uniform(rng, 0.5, 50.0),
        iops: uniform(rng, 500.0, 50_000.0),
        cost: uniform(rng, 0.01, 5.0),
    }
}

fn random_model(rng: &mut XorShift64) -> SurfaceModel {
    let cfg = ModelConfig::default_paper();
    let tiers = (0..4)
        .map(|i| random_tier(rng, &format!("t{i}")))
        .collect();
    let plane = ScalingPlane::new(vec![1, 2, 4, 8], tiers);
    SurfaceModel::new(plane, cfg.surfaces, 0.3)
}

#[test]
fn surfaces_finite_and_signed_for_random_tiers() {
    forall(200, 0xB1, |_, rng| {
        let m = random_model(rng);
        let lam = uniform(rng, 1.0, 100_000.0);
        for c in m.plane().iter() {
            let p = m.evaluate(&c, lam);
            assert!(p.latency.is_finite() && p.latency > 0.0);
            assert!(p.throughput.is_finite() && p.throughput > 0.0);
            assert!(p.cost.is_finite() && p.cost >= 0.0);
            assert!(p.coordination.is_finite() && p.coordination >= 0.0);
            assert!(p.objective.is_finite());
        }
    });
}

#[test]
fn latency_rises_with_node_count_for_any_tier() {
    forall(200, 0xB2, |_, rng| {
        let m = random_model(rng);
        for v in 0..4 {
            for h in 0..3 {
                assert!(
                    m.latency(&Configuration::new(h + 1, v))
                        > m.latency(&Configuration::new(h, v)),
                    "coordination latency must grow with H"
                );
            }
        }
    });
}

#[test]
fn better_resources_never_raise_node_latency() {
    // improving a single tier resource strictly lowers L_node
    let cfg = ModelConfig::default_paper();
    let plane = cfg.plane();
    let m = SurfaceModel::from_config(&cfg);
    forall(200, 0xB3, |_, rng| {
        let base = plane.tiers()[rng.below(4) as usize].clone();
        let mut better = base.clone();
        match rng.below(4) {
            0 => better.cpu *= 2.0,
            1 => better.ram *= 2.0,
            2 => better.bandwidth *= 2.0,
            _ => better.iops *= 2.0,
        }
        assert!(m.node_latency(&better) < m.node_latency(&base));
    });
}

#[test]
fn throughput_monotone_in_h_and_sublinear() {
    forall(200, 0xB4, |_, rng| {
        let m = random_model(rng);
        for v in 0..4 {
            for h in 0..3 {
                let lo = m.throughput(&Configuration::new(h, v));
                let hi = m.throughput(&Configuration::new(h + 1, v));
                assert!(hi > lo, "adding nodes must add capacity");
                assert!(hi < 2.0 * lo + 1e-3, "phi(H) < 1: sublinear scaling");
            }
        }
    });
}

#[test]
fn throughput_tracks_the_bottleneck_resource() {
    let cfg = ModelConfig::default_paper();
    let m = SurfaceModel::from_config(&cfg);
    forall(200, 0xB5, |_, rng| {
        let mut t = random_tier(rng, "x");
        let before = m.node_throughput(&t);
        // raising a non-bottleneck resource never changes T_node
        let min = t.min_resource();
        if t.cpu > min {
            t.cpu *= 2.0;
            assert_eq!(m.node_throughput(&t), before);
        }
    });
}

#[test]
fn cost_is_bilinear() {
    forall(200, 0xB6, |_, rng| {
        let m = random_model(rng);
        let plane = m.plane();
        for c in plane.iter() {
            let want = plane.h_value(&c) as f32 * plane.tier(&c).cost;
            assert_eq!(m.cost(&c), want);
        }
    });
}

#[test]
fn effective_latency_bounds() {
    forall(300, 0xB7, |_, rng| {
        let lat = uniform(rng, 0.1, 20.0);
        let thr = uniform(rng, 10.0, 100_000.0);
        let u_max = uniform(rng, 0.1, 0.99);
        let lam = uniform(rng, 0.0, 1.0e9);
        let l_eff = queueing::effective_latency(lat, thr, lam, u_max);
        assert!(l_eff >= lat, "queueing can only add latency");
        assert!(l_eff <= lat / (1.0 - u_max) + 1e-3, "clamp bounds the blowup");
        assert!(l_eff.is_finite());
    });
}

#[test]
fn effective_latency_monotone_in_demand() {
    forall(200, 0xB8, |_, rng| {
        let lat = uniform(rng, 0.1, 20.0);
        let thr = uniform(rng, 100.0, 100_000.0);
        let lam_a = uniform(rng, 0.0, thr);
        let lam_b = lam_a + uniform(rng, 0.0, thr);
        let a = queueing::effective_latency(lat, thr, lam_a, 0.95);
        let b = queueing::effective_latency(lat, thr, lam_b, 0.95);
        assert!(b >= a - 1e-6);
    });
}

#[test]
fn planner_feasible_implies_audit_clean() {
    // with b_sla >= 1, a planner-feasible config can never be an SLA
    // violation when served at the same demand
    let cfg = ModelConfig::default_paper();
    let m = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    assert!(cfg.sla.b_sla >= 1.0);
    forall(300, 0xB9, |_, rng| {
        let c = Configuration::new(rng.below(4) as usize, rng.below(4) as usize);
        let lam = uniform(rng, 1.0, 60_000.0);
        if m.feasible(&c, lam, &sla, false) {
            let v = sla.audit(m.latency(&c), m.throughput(&c), lam);
            assert!(!v.any(), "feasible config audited as violating at {c:?}");
        }
    });
}

#[test]
fn best_feasible_agrees_with_exhaustive_scan() {
    let cfg = ModelConfig::default_paper();
    let m = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    forall(200, 0xBA, |_, rng| {
        let lam = uniform(rng, 1.0, 60_000.0);
        let fast = m.best_feasible(lam, &sla, false);
        // brute force
        let mut brute: Option<(Configuration, f32)> = None;
        for c in m.plane().iter() {
            if !m.feasible(&c, lam, &sla, false) {
                continue;
            }
            let obj = m.evaluate(&c, lam).objective;
            if brute.map_or(true, |(_, b)| obj < b) {
                brute = Some((c, obj));
            }
        }
        match (fast, brute) {
            (None, None) => {}
            (Some((fc, _)), Some((bc, _))) => assert_eq!(fc, bc),
            (f, b) => panic!("mismatch: fast={f:?} brute={b:?}"),
        }
    });
}

#[test]
fn grid_evaluation_consistent_with_point_evaluation() {
    forall(100, 0xBB, |_, rng| {
        let m = random_model(rng);
        let lam = uniform(rng, 1.0, 50_000.0);
        for (c, p) in m.evaluate_grid(lam) {
            let q = m.evaluate(&c, lam);
            assert_eq!(p, q);
        }
    });
}
