//! The bounded-memory observation pin (ISSUE 9 acceptance): a
//! streaming fleet must make exactly the decisions of an exact-recording
//! fleet while retaining O(cap) records per tenant instead of O(ticks),
//! with summaries bit-identical, p95 inside one sketch bucket, and the
//! exemplar reservoir provably uniform (chi-square at p = 0.001).

use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{FleetSimulator, PriorityClass, TenantSpec};
use diagonal_scale::metrics::{Recorder, StepRecord, StreamingRecorder};
use diagonal_scale::plane::Configuration;
use diagonal_scale::serverless::{mostly_idle_specs, ServerlessParams};
use diagonal_scale::sla::Violation;
use diagonal_scale::workload::{TraceBuilder, XorShift64};

/// The CLI's fleet scenario: paper timeline phase-shifted per tenant,
/// top quarter Gold, next quarter Silver, rest Bronze.
fn staggered_specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
    let base = TraceBuilder::paper(cfg);
    (0..n)
        .map(|i| {
            let class = if 4 * i < n {
                PriorityClass::Gold
            } else if 2 * i < n {
                PriorityClass::Silver
            } else {
                PriorityClass::Bronze
            };
            TenantSpec::from_config(
                cfg,
                format!("tenant-{i:02}"),
                class,
                base.shifted(i * base.len() / n),
            )
        })
        .collect()
}

fn total_retained(fleet: &FleetSimulator) -> usize {
    fleet.tenants().iter().map(|t| t.retained_records()).sum()
}

/// Exact nearest-rank percentile over a record stream (the oracle the
/// sketch quantile is pinned against).
fn exact_percentile(latencies: &mut [f64], q: f64) -> f64 {
    latencies.sort_by(f64::total_cmp);
    let rank = ((q * latencies.len() as f64).ceil() as usize).clamp(1, latencies.len());
    latencies[rank - 1]
}

/// The acceptance pin: 512 tenants, identical decision timelines, and
/// retained observation memory constant in tick count under streaming
/// (vs linear for the exact recorder).
#[test]
fn streaming_fleet_is_decision_identical_with_constant_memory() {
    let cfg = ModelConfig::default_paper();
    let (n, cap) = (512usize, 32usize);
    let budget = 2.2 * n as f32;
    let mut exact = FleetSimulator::new(&cfg, staggered_specs(&cfg, n), budget, 3);
    let mut stream = FleetSimulator::new(&cfg, staggered_specs(&cfg, n), budget, 3);
    stream.enable_streaming_metrics(cap);

    let mut checkpoints = Vec::new();
    for t in 0..120 {
        let a = exact.tick();
        let b = stream.tick();
        assert_eq!(a, b, "decision timelines diverged at tick {t}");
        if t == 59 || t == 119 {
            checkpoints.push((total_retained(&exact), total_retained(&stream)));
        }
    }
    // exact memory grows linearly with ticks; streaming memory is flat
    assert_eq!(checkpoints[0].0, n * 60);
    assert_eq!(checkpoints[1].0, n * 120);
    assert_eq!(checkpoints[0].1, n * cap);
    assert_eq!(checkpoints[1].1, n * cap);

    // summaries are bit-identical (same folds, same push order)...
    for (te, ts) in exact.tenants().iter().zip(stream.tenants()) {
        assert_eq!(te.summary(), ts.summary(), "summary diverged");
    }
    // ...and streaming p95/p99 land inside one sketch bucket of the
    // exact nearest-rank value (bucket edges are 2^(1/8) apart)
    let one_bucket = 2f64.powf(1.0 / 8.0);
    for (te, ts) in exact.tenants().iter().zip(stream.tenants()) {
        let s = ts.streaming().expect("streaming fleet tenant has a streaming recorder");
        for q in [0.95, 0.99] {
            let mut lat: Vec<f64> = te.records().iter().map(|r| r.latency as f64).collect();
            let oracle = exact_percentile(&mut lat, q);
            let sketch = s.latency_histogram().quantile(q);
            assert!(
                sketch <= oracle * one_bucket && sketch >= oracle / one_bucket,
                "q {q}: sketch {sketch} vs exact {oracle}"
            );
        }
    }
}

/// Streaming-vs-exact equivalence must also hold through the
/// serverless lifecycle (suspends produce zero-latency records that
/// land in the sketch's underflow bucket).
#[test]
fn streaming_matches_exact_through_suspend_resume() {
    let cfg = ModelConfig::default_paper();
    let build = |streaming: bool| {
        let mut f =
            FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, 24, 0.75), 1.0e6, 3);
        f.enable_serverless(ServerlessParams::default());
        if streaming {
            f.enable_streaming_metrics(16);
        }
        f
    };
    let mut exact = build(false);
    let mut stream = build(true);
    let a = exact.run(90);
    let b = stream.run(90);
    assert_eq!(a.ticks, b.ticks, "serverless decision timelines diverged");
    assert!(a.ticks.iter().any(|t| t.suspended > 0), "scenario must exercise suspends");
    for (te, ts) in exact.tenants().iter().zip(stream.tenants()) {
        assert_eq!(te.summary(), ts.summary());
    }
}

fn exemplar(step: usize) -> StepRecord {
    StepRecord {
        step,
        config: Configuration::new(1, 1),
        lambda_req: 1000.0,
        latency: 0.01,
        latency_raw: 0.009,
        throughput: 2000.0,
        cost: 1.0,
        objective: 0.1,
        violation: Violation { latency: false, throughput: false },
    }
}

/// Algorithm R must sample uniformly: decile occupancy of reservoir
/// survivors over a 10k-record stream, aggregated across four seeds,
/// stays under the chi-square p = 0.001 critical value for 9 degrees
/// of freedom (27.88). Fully seeded, so the statistic is a constant
/// (≈ 22.4), not a flaky draw.
#[test]
fn reservoir_sampling_is_uniform_across_the_stream() {
    let (n, cap) = (10_000usize, 100usize);
    let mut deciles = [0usize; 10];
    let seeds = [0x5EED_0001u64, 0x5EED_0002, 0x5EED_0003, 0x5EED_0004];
    for &seed in &seeds {
        let mut s = StreamingRecorder::new(cap, seed);
        for i in 0..n {
            s.push(exemplar(i));
        }
        assert_eq!(s.retained(), cap);
        for r in s.sample() {
            deciles[r.step * 10 / n] += 1;
        }
    }
    let expected = (seeds.len() * cap) as f64 / 10.0;
    let chi2: f64 =
        deciles.iter().map(|&c| (c as f64 - expected).powi(2) / expected).sum();
    assert!(
        chi2 < 27.88,
        "decile counts {deciles:?} give chi-square {chi2:.2} ≥ 27.88 (p = 0.001, 9 dof)"
    );
}

/// The streaming summary is pinned bitwise against the exact oracle on
/// a long random stream (not just the in-module unit test's 500).
#[test]
fn streaming_summary_equals_exact_oracle_on_random_streams() {
    for seed in [5u64, 1234, 0xDEAD] {
        let mut rng = XorShift64::new(seed);
        let mut exact = Recorder::new();
        let mut stream = StreamingRecorder::new(8, seed);
        for i in 0..20_000 {
            let mut r = exemplar(i);
            r.latency = (rng.next_f64() * 0.05) as f32;
            r.latency_raw = r.latency * 0.9;
            r.cost = 0.4 + (rng.next_f64() * 2.0) as f32;
            r.violation = Violation { latency: rng.next_f64() < 0.05, throughput: false };
            exact.push(r);
            stream.push(r);
        }
        assert_eq!(exact.summary(), stream.summary());
        assert_eq!(stream.retained(), 8);
        assert_eq!(stream.len(), 20_000);
    }
}
