//! Fixture: a mini metrics name table for the S2 rule.
//! Doc-comment decoy the scanner must ignore:
//! `pub const FAKE: &str = "not_a_metric";`

pub const FLEET_TICKS_TOTAL: &str = "fleet_ticks_total";
pub const FLEET_SPEND_HOURLY: &str = "fleet_spend_hourly";
pub const ARBITER_BUDGET_HOURLY: &str = "arbiter_budget_hourly";

// decoys: not &str metric-name consts
pub const UNRELATED_COUNT: usize = 3;
pub const HELP_TEXT: &'static str = "help text, not a metric name";
