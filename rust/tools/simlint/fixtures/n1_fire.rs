//! N1 firing fixture: money in f32. Expected findings: 3 (an f32
//! money accumulator, a bare `as f32` narrowing of a money
//! identifier, and a money sum collected in f32).

pub fn tally(costs: &[f32]) -> f32 {
    let mut spend = 0.0f32;
    for c in costs {
        spend += *c;
    }
    spend
}

pub fn narrow_direct(total_cost: f64) -> f32 {
    total_cost as f32
}

pub fn sum_budget(parts: &[f32]) -> f32 {
    parts.iter().map(|p| budget_of(*p)).sum::<f32>()
}

fn budget_of(x: f32) -> f32 {
    x * 2.0
}
