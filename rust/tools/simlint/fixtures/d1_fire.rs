//! D1 firing fixture: wall-clock reads inside simulation/decision
//! code. Expected findings: 3 (Instant::now, SystemTime in a
//! signature, SystemTime::now).

pub fn epoch_micros() -> u64 {
    let start = std::time::Instant::now();
    start.elapsed().as_micros() as u64
}

pub fn wall() -> std::time::SystemTime {
    std::time::SystemTime::now()
}
