//! N1 passing fixture: money accumulates in f64 and is narrowed once
//! at a justified edge; non-money f32 narrowing is fine.

pub fn tally(costs: &[f32]) -> f32 {
    let mut spend = 0.0f64;
    for c in costs {
        spend += *c as f64;
    }
    narrow(spend)
}

pub fn narrow(money: f64) -> f32 {
    // simlint: allow(n1-money-in-f64): the single sanctioned f64->f32 money edge.
    money as f32
}

pub fn utilization(frac: f64) -> f32 {
    frac.max(0.0) as f32
}
