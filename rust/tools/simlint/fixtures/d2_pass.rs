//! D2 passing fixture: ordered containers iterate deterministically.
//! A HashMap mention in this comment must not fire.

use std::collections::BTreeMap;

pub fn index(keys: &[u32]) -> BTreeMap<u32, usize> {
    let mut map = BTreeMap::new();
    for (i, k) in keys.iter().enumerate() {
        map.insert(*k, i);
    }
    map
}
