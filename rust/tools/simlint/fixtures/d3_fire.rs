//! D3 firing fixture: partial float ordering. Expected findings: 2
//! (a partial_cmp().unwrap() sort key, and a hand-rolled PartialOrd
//! that does not delegate to a total Ord). The partial_cmp call
//! *inside* the impl body must not double-report.

pub fn pick(xs: &mut [(f32, u32)]) {
    xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
}

pub struct Key(pub f64);

impl PartialEq for Key {
    fn eq(&self, other: &Self) -> bool {
        self.0 == other.0
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        self.0.partial_cmp(&other.0)
    }
}
