//! D2 firing fixture: unordered containers in decision code.
//! Expected findings: 3 (use line, signature, constructor).

use std::collections::HashMap;

pub fn index(keys: &[u32]) -> HashMap<u32, usize> {
    let mut map = HashMap::new();
    for (i, k) in keys.iter().enumerate() {
        map.insert(*k, i);
    }
    map
}
