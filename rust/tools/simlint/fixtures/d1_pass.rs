//! D1 passing fixture: time flows through the injectable planning
//! clock, and mentions of Instant::now in comments or strings must
//! not fire (the scanner strips both).

pub struct Planner {
    clock: Box<dyn Fn() -> u64 + Send>,
}

impl Planner {
    // Instant::now() would fire here if comment stripping were broken.
    pub fn set_planning_clock(&mut self, clock: Box<dyn Fn() -> u64 + Send>) {
        self.clock = clock;
    }

    pub fn planning_micros(&self) -> u64 {
        let banned = "Instant::now and SystemTime only appear in this string";
        let _ = banned;
        (self.clock)()
    }
}
