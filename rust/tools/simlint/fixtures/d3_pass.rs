//! D3 passing fixture: total float ordering — sorts via total_cmp,
//! and PartialOrd delegates to an Ord built on total_cmp.

use std::cmp::Ordering;

#[derive(PartialEq)]
pub struct Key(pub f64);

impl Eq for Key {}

impl Ord for Key {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

pub fn pick(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
