//! S1 fixture: a miniature report module whose emitters hand-roll
//! JSON the same way rust/src/report/mod.rs does. Emitted keys:
//! schema, v, cost, tenant, score.

pub fn explain_json(v: u32, cost: f64) -> String {
    format!("{{\"schema\":\"demo/explain-v1\",\"v\":{v},\"cost\":{cost}}}")
}

pub fn fleet_explain_json_sampled(tenant: u32, score: f64) -> String {
    let mut out = String::from("{");
    out.push_str(&format!("\"tenant\":{tenant},\"score\":{score}"));
    out.push('}');
    out
}

pub fn not_an_emitter() -> String {
    // keys outside the explain emitters are not part of the schema
    "{\"unrelated\":1}".to_string()
}
