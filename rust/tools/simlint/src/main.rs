//! `simlint` CLI — lint the repo and print findings as
//! `path:line [rule-id] message` (or `--json`).
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

fn usage() -> ExitCode {
    eprintln!("usage: simlint [--json] [--root <dir>]");
    eprintln!();
    eprintln!("Scans rust/src, rust/tests, rust/benches, and Cargo.toml under");
    eprintln!("<dir> (default: current directory, walking up to find rust/src)");
    eprintln!("and enforces the diagonal-scale invariants:");
    for (id, summary) in simlint::RULES {
        eprintln!("  {id:<28} {summary}");
    }
    ExitCode::from(2)
}

/// Find the repo root: `--root` if given, else walk up from cwd until
/// a directory containing `rust/src` appears.
fn find_root(explicit: Option<PathBuf>) -> Option<PathBuf> {
    if let Some(r) = explicit {
        return Some(r);
    }
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let mut json = false;
    let mut root: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--json" => json = true,
            "--root" => match args.next() {
                Some(dir) => root = Some(PathBuf::from(dir)),
                None => return usage(),
            },
            "--help" | "-h" => return usage(),
            _ => return usage(),
        }
    }
    let Some(root) = find_root(root) else {
        eprintln!("simlint: no repo root found (no rust/src upward of cwd); use --root");
        return ExitCode::from(2);
    };
    let report = match simlint::lint_repo(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simlint: {e}");
            return ExitCode::from(2);
        }
    };
    if json {
        println!("{}", simlint::to_json(&report));
    } else {
        for f in &report.findings {
            println!("{}:{} [{}] {}", f.path, f.line, f.rule, f.message);
        }
        println!(
            "simlint: {} file(s) scanned, {} finding(s), {} allow directive(s), \
             {} suppressed",
            report.files_scanned,
            report.findings.len(),
            report.allow_directives,
            report.suppressed
        );
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
