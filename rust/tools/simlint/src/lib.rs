//! `simlint` — repo-native static analysis for diagonal-scale.
//!
//! Every pinned result in this repo (dirty-queue decision identity,
//! bitwise spend equality, packed-vs-dedicated cost ratios) rests on
//! invariants that used to be enforced only by reviewer vigilance.
//! This tool mechanizes them as a push gate:
//!
//! * **D1 `d1-no-wall-clock`** — `Instant::now` / `SystemTime` are
//!   banned in simulation/decision code (`rust/src`, minus `benchkit`).
//!   Non-reproducible decisions are undebuggable at 10k tenants; time
//!   flows through the injectable planning-clock seam
//!   (`FleetSimulator::set_planning_clock`).
//! * **D2 `d2-no-unordered-iteration`** — `HashMap`/`HashSet` are
//!   banned in `rust/src` (minus the PJRT `runtime` stub): unordered
//!   iteration makes decision replay nondeterministic. Use `BTreeMap`,
//!   `BTreeSet`, or an indexed `Vec`.
//! * **D3 `d3-total-order-floats`** — float ordering must go through
//!   `total_cmp`: `partial_cmp(..).unwrap()` call sites are flagged,
//!   and hand-rolled `PartialOrd` impls must delegate to a total `Ord`
//!   (`Some(self.cmp(..))`).
//! * **N1 `n1-money-in-f64`** — money accumulates in `f64` (PR 7
//!   caught a real f32 spend-drift bug only via a hand-written
//!   mirror). Flags f32 `let mut` accumulators with money-ish names,
//!   `.sum::<f32>()` over money expressions, and `as f32` narrowing of
//!   money identifiers outside the one sanctioned edge
//!   (`util::money::narrow`).
//! * **S1 `s1-explain-additivity`** — the JSON keys emitted by
//!   `report::explain_json` / `report::fleet_explain_json*` are
//!   diffed against the `config/explain_v1.keys` snapshot: removals
//!   and renames fail (the schema is additive-only), additions fail
//!   until the snapshot is updated in the same PR, which makes every
//!   schema change reviewable.
//! * **S2 `s2-metrics-additivity`** — the metric-name consts declared
//!   in `rust/src/metrics/names.rs` are diffed against the
//!   `config/metrics_v1.names` snapshot: scrape configs, dashboards,
//!   and alerts key on these names, so removals and renames fail, and
//!   additions fail until the snapshot is updated in the same PR.
//! * **T1 `t1-registration`** — every file in `rust/tests` and
//!   `rust/benches` must have a matching `[[test]]`/`[[bench]]` path
//!   entry in `Cargo.toml` and vice versa (auto-discovery is off, so a
//!   dropped file would otherwise silently never run).
//!
//! ## Escape hatch
//!
//! `// simlint: allow(<rule-id>): <justification>` suppresses findings
//! on its own line and the line directly below. The justification is
//! mandatory — a bare `allow(...)` is itself a finding — and the total
//! number of inline allows across the tree is capped at
//! [`MAX_ALLOWS`].
//!
//! The scanner is a deliberately dependency-free line/token pass (the
//! build is offline-only, so no `syn`): comments and string contents
//! are blanked by a small state machine before token rules run, and
//! brace counting on the blanked text recovers function bodies where a
//! rule needs them (D3 delegation, S1 key extraction).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Rule id: no wall clock in simulation/decision code.
pub const D1: &str = "d1-no-wall-clock";
/// Rule id: no unordered-iteration containers in decision code.
pub const D2: &str = "d2-no-unordered-iteration";
/// Rule id: float ordering must be total.
pub const D3: &str = "d3-total-order-floats";
/// Rule id: money accumulates in f64, narrowed once at the edge.
pub const N1: &str = "n1-money-in-f64";
/// Rule id: explain-v1 key set matches the checked-in snapshot.
pub const S1: &str = "s1-explain-additivity";
/// Rule id: metrics-v1 name set matches the checked-in snapshot.
pub const S2: &str = "s2-metrics-additivity";
/// Rule id: tests/benches reconcile with Cargo.toml registration.
pub const T1: &str = "t1-registration";
/// Rule id: an allow directive without a justification.
pub const ALLOW: &str = "allow-needs-justification";
/// Rule id: too many inline allows across the tree.
pub const ALLOW_BUDGET: &str = "allow-budget";

/// Maximum inline `// simlint: allow(...)` directives tolerated across
/// the whole tree before the gate fails: the escape hatch is for the
/// few sanctioned seams, not for wholesale suppression.
pub const MAX_ALLOWS: usize = 6;

/// Every rule id with a one-line summary (drives `--json` and docs).
pub const RULES: &[(&str, &str)] = &[
    (D1, "wall clock banned in sim/decision modules (inject via set_planning_clock)"),
    (D2, "HashMap/HashSet banned in decision modules (BTreeMap/BTreeSet/indexed Vec)"),
    (D3, "float ordering must use total_cmp / delegate PartialOrd to a total Ord"),
    (N1, "money accumulates in f64; f32 money accumulators and narrowing flagged"),
    (S1, "explain-v1 JSON keys must match config/explain_v1.keys (additive-only)"),
    (S2, "metrics-v1 names must match config/metrics_v1.names (additive-only)"),
    (T1, "rust/tests + rust/benches must reconcile with Cargo.toml [[test]]/[[bench]]"),
    (ALLOW, "simlint: allow(...) requires a justification after the closing paren"),
    (ALLOW_BUDGET, "inline allow directives are capped tree-wide"),
];

/// Identifier substrings that mark a binding as monetary for N1.
pub const MONEY_TOKENS: &[&str] = &["cost", "spend", "budget", "price", "money"];

/// One diagnostic: `path:line` + rule id + message.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// 1-based line number (0 = whole-file finding).
    pub line: usize,
    /// Rule id from [`RULES`].
    pub rule: &'static str,
    /// Human-oriented explanation with the suggested fix.
    pub message: String,
}

impl Finding {
    fn new(path: &str, line0: usize, rule: &'static str, message: String) -> Self {
        Self { path: path.to_string(), line: line0 + 1, rule, message }
    }
}

/// An inline `// simlint: allow(rule): why` directive.
#[derive(Debug, Clone)]
pub struct AllowDirective {
    /// 0-based line the directive sits on.
    pub line: usize,
    /// Rule id inside the parens.
    pub rule: String,
    /// Whether a non-empty justification follows `):`.
    pub justified: bool,
}

/// A source file preprocessed for the token rules.
pub struct ScannedFile {
    /// Repo-relative path with forward slashes.
    pub path: String,
    /// Raw source lines (used for S1 key extraction + allow parsing).
    pub raw: Vec<String>,
    /// Lines with comments and string/char contents blanked.
    pub code: Vec<String>,
    /// Parsed allow directives.
    pub allows: Vec<AllowDirective>,
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Token-boundary substring search: `tok` must not be embedded in a
/// longer identifier (but may be reached through `::` paths).
pub fn has_token(line: &str, tok: &str) -> bool {
    find_token(line, tok).is_some()
}

fn find_token(line: &str, tok: &str) -> Option<usize> {
    let lb = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(tok) {
        let i = start + pos;
        let before_ok = i == 0 || !is_ident_byte(lb[i - 1]);
        let after = i + tok.len();
        let after_ok = after >= lb.len() || !is_ident_byte(lb[after]);
        if before_ok && after_ok {
            return Some(i);
        }
        start = i + 1;
    }
    None
}

/// Whether any identifier in `text` contains a money token.
pub fn mentions_money(text: &str) -> bool {
    let b = text.as_bytes();
    let mut i = 0;
    while i < b.len() {
        if is_ident_byte(b[i]) && !b[i].is_ascii_digit() {
            let start = i;
            while i < b.len() && is_ident_byte(b[i]) {
                i += 1;
            }
            if ident_is_money(&text[start..i]) {
                return true;
            }
        } else {
            i += 1;
        }
    }
    false
}

fn ident_is_money(ident: &str) -> bool {
    let lower = ident.to_ascii_lowercase();
    MONEY_TOKENS.iter().any(|m| lower.contains(m))
}

/// Blank comments and string/char-literal contents, preserving line
/// structure and delimiters, so token rules cannot fire inside text
/// and brace counting sees only structural braces.
pub fn strip_source(src: &str) -> Vec<String> {
    #[derive(PartialEq)]
    enum St {
        Code,
        Line,
        Block(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let chars: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut st = St::Code;
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if st == St::Line {
                st = St::Code;
            }
            out.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::Line;
                    cur.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(1);
                    cur.push_str("  ");
                    i += 2;
                } else if c == '"' {
                    st = St::Str;
                    cur.push('"');
                    i += 1;
                } else if c == 'r'
                    && matches!(next, Some('"') | Some('#'))
                    && (i == 0 || !is_ident_byte(chars[i - 1] as u8))
                {
                    let mut hashes = 0u32;
                    let mut j = i + 1;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if chars.get(j) == Some(&'"') {
                        st = St::RawStr(hashes);
                        for _ in i..j {
                            cur.push(' ');
                        }
                        cur.push('"');
                        i = j + 1;
                    } else {
                        cur.push(c);
                        i += 1;
                    }
                } else if c == '\''
                    && (next == Some('\\')
                        || (chars.get(i + 2) == Some(&'\'') && next != Some('\'')))
                {
                    st = St::Char;
                    cur.push('\'');
                    i += 1;
                } else {
                    cur.push(c);
                    i += 1;
                }
            }
            St::Line => {
                cur.push(' ');
                i += 1;
            }
            St::Block(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    cur.push_str("  ");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::Block(depth + 1);
                    cur.push_str("  ");
                    i += 2;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            St::Str | St::Char => {
                let close = if st == St::Str { '"' } else { '\'' };
                if c == '\\' {
                    cur.push(' ');
                    i += 1;
                    if chars.get(i).is_some_and(|&n| n != '\n') {
                        cur.push(' ');
                        i += 1;
                    }
                } else if c == close {
                    st = St::Code;
                    cur.push(close);
                    i += 1;
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let closed =
                        (0..hashes as usize).all(|k| chars.get(i + 1 + k) == Some(&'#'));
                    if closed {
                        st = St::Code;
                        cur.push('"');
                        for _ in 0..hashes {
                            cur.push(' ');
                        }
                        i += 1 + hashes as usize;
                    } else {
                        cur.push(' ');
                        i += 1;
                    }
                } else {
                    cur.push(' ');
                    i += 1;
                }
            }
        }
    }
    out.push(cur);
    out
}

fn parse_allows(raw: &[String]) -> Vec<AllowDirective> {
    let mut out = Vec::new();
    for (idx, line) in raw.iter().enumerate() {
        let Some(comment) = line.find("//") else { continue };
        let tail = &line[comment..];
        let Some(pos) = tail.find("simlint: allow(") else { continue };
        let after = &tail[pos + "simlint: allow(".len()..];
        let Some(close) = after.find(')') else { continue };
        let rule = after[..close].trim().to_string();
        let rest = &after[close + 1..];
        let justified = rest
            .strip_prefix(':')
            .map(|j| !j.trim().is_empty())
            .unwrap_or(false);
        out.push(AllowDirective { line: idx, rule, justified });
    }
    out
}

impl ScannedFile {
    /// Preprocess one source file.
    pub fn parse(path: &str, src: &str) -> Self {
        let raw: Vec<String> = src.split('\n').map(str::to_string).collect();
        let code = strip_source(src);
        let allows = parse_allows(&raw);
        Self { path: path.to_string(), raw, code, allows }
    }

    /// Whether a justified allow for `rule` covers 0-based `line`
    /// (the directive's own line or the line directly below it).
    pub fn allowed(&self, line: usize, rule: &str) -> bool {
        self.allows.iter().any(|a| {
            a.justified && a.rule == rule && (a.line == line || a.line + 1 == line)
        })
    }

    /// Findings for malformed allow directives (missing justification
    /// or unknown rule id). These are never suppressible.
    pub fn allow_findings(&self) -> Vec<Finding> {
        let known: BTreeSet<&str> = RULES.iter().map(|(id, _)| *id).collect();
        let mut out = Vec::new();
        for a in &self.allows {
            if !known.contains(a.rule.as_str()) {
                out.push(Finding::new(
                    &self.path,
                    a.line,
                    ALLOW,
                    format!("allow({}) names an unknown rule id", a.rule),
                ));
            } else if !a.justified {
                out.push(Finding::new(
                    &self.path,
                    a.line,
                    ALLOW,
                    format!(
                        "allow({}) has no justification: write `// simlint: allow({}): <why>`",
                        a.rule, a.rule
                    ),
                ));
            }
        }
        out
    }
}

/// End line (0-based, inclusive) of the block opened at/after `start`.
fn body_end(code: &[String], start: usize) -> usize {
    let mut depth = 0i64;
    let mut opened = false;
    for (k, line) in code.iter().enumerate().skip(start) {
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return k;
        }
    }
    code.len().saturating_sub(1)
}

// ---------------------------------------------------------------- D1/D2

fn in_scope_d1(path: &str) -> bool {
    path.starts_with("rust/src/") && !path.starts_with("rust/src/benchkit")
}

fn in_scope_d2(path: &str) -> bool {
    path.starts_with("rust/src/") && !path.starts_with("rust/src/runtime")
}

fn rule_d1(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (idx, line) in f.code.iter().enumerate() {
        if has_token(line, "Instant::now") || has_token(line, "SystemTime") {
            out.push(Finding::new(
                &f.path,
                idx,
                D1,
                "wall-clock read in simulation/decision code: decisions must replay \
                 bit-for-bit; route time through the injectable planning clock \
                 (FleetSimulator::set_planning_clock) or keep measurement in benchkit"
                    .to_string(),
            ));
        }
    }
}

fn rule_d2(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (idx, line) in f.code.iter().enumerate() {
        if has_token(line, "HashMap") || has_token(line, "HashSet") {
            out.push(Finding::new(
                &f.path,
                idx,
                D2,
                "HashMap/HashSet iterate in nondeterministic order: use BTreeMap/BTreeSet \
                 or an indexed Vec so decision replay is reproducible"
                    .to_string(),
            ));
        }
    }
}

// ------------------------------------------------------------------- D3

fn rule_d3(f: &ScannedFile, out: &mut Vec<Finding>) {
    let mut consumed: BTreeSet<usize> = BTreeSet::new();
    for idx in 0..f.code.len() {
        if consumed.contains(&idx) {
            continue;
        }
        let line = &f.code[idx];
        if !has_token(line, "partial_cmp") {
            continue;
        }
        if line.contains("fn partial_cmp") {
            // a PartialOrd impl: the body must delegate to a total Ord
            let end = body_end(&f.code, idx);
            let body = f.code[idx..=end].join(" ");
            for k in idx..=end {
                consumed.insert(k);
            }
            if !body.contains("self.cmp(") {
                out.push(Finding::new(
                    &f.path,
                    idx,
                    D3,
                    "hand-rolled PartialOrd over floats: delegate with `Some(self.cmp(..))` \
                     to an Ord impl built on total_cmp (partial float order is not \
                     reproducible under NaN)"
                        .to_string(),
                ));
            }
        } else {
            out.push(Finding::new(
                &f.path,
                idx,
                D3,
                "float ordering through partial_cmp: use f32::total_cmp/f64::total_cmp \
                 (total over NaN, so sorts and heap keys are deterministic)"
                    .to_string(),
            ));
        }
    }
}

// ------------------------------------------------------------------- N1

/// Money identifiers immediately narrowed by `as f32` on this line
/// (handles `spend as f32`, `spend_f64() as f32`, `arr[i] as f32`).
fn narrowed_money_idents(line: &str) -> Vec<(usize, String)> {
    let b = line.as_bytes();
    let mut out = Vec::new();
    let mut start = 0;
    while let Some(pos) = line[start..].find("as f32") {
        let i = start + pos;
        start = i + 1;
        // token boundaries around `as f32`
        let end = i + "as f32".len();
        if end < b.len() && is_ident_byte(b[end]) {
            continue;
        }
        if i == 0 || !b[i - 1].is_ascii_whitespace() {
            continue;
        }
        // walk back over whitespace to the narrowed expression
        let mut j = i - 1;
        while j > 0 && b[j].is_ascii_whitespace() {
            j -= 1;
        }
        // skip one trailing call/index group: `ident(...)` / `ident[...]`
        if b[j] == b')' || b[j] == b']' {
            let (open, close) = if b[j] == b')' { (b'(', b')') } else { (b'[', b']') };
            let mut depth = 0i32;
            loop {
                if b[j] == close {
                    depth += 1;
                } else if b[j] == open {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                if j == 0 {
                    break;
                }
                j -= 1;
            }
            if j == 0 {
                continue;
            }
            j -= 1;
        }
        if !is_ident_byte(b[j]) {
            continue;
        }
        let ident_end = j + 1;
        let mut ident_start = j;
        while ident_start > 0 && is_ident_byte(b[ident_start - 1]) {
            ident_start -= 1;
        }
        let ident = &line[ident_start..ident_end];
        if ident_is_money(ident) {
            out.push((i, ident.to_string()));
        }
    }
    out
}

/// Statement-ish lookback window for a chained `.sum::<f32>()`: join
/// up to 10 preceding lines, stopping at a `;` statement end or a `fn`
/// signature boundary.
fn statement_window(code: &[String], line: usize) -> String {
    let mut parts = vec![code[line].clone()];
    let mut k = line;
    let mut steps = 0;
    while k > 0 && steps < 10 {
        k -= 1;
        let prev = code[k].trim();
        if prev.ends_with(';') || has_token(prev, "fn") {
            break;
        }
        parts.push(prev.to_string());
        steps += 1;
    }
    parts.reverse();
    parts.join(" ")
}

fn rule_n1(f: &ScannedFile, out: &mut Vec<Finding>) {
    for (idx, line) in f.code.iter().enumerate() {
        // (a) f32 `let mut` accumulator with a money-ish name
        if let Some(pos) = line.find("let mut ") {
            let rest = &line[pos + "let mut ".len()..];
            let name: String =
                rest.chars().take_while(|c| is_ident_byte(*c as u8)).collect();
            if ident_is_money(&name) {
                let mut stmt = line.clone();
                if !line.contains(';') {
                    for extra in f.code.iter().skip(idx + 1).take(2) {
                        stmt.push(' ');
                        stmt.push_str(extra);
                    }
                }
                if stmt.contains("f32") {
                    out.push(Finding::new(
                        &f.path,
                        idx,
                        N1,
                        format!(
                            "f32 money accumulator `{name}`: an f32 running sum loses real \
                             pennies by 10k tenants (the PR-7 drift bug) — accumulate in \
                             f64 and narrow once via util::money::narrow"
                        ),
                    ));
                }
            }
        }
        // (b) money identifier narrowed with `as f32`
        for (_, ident) in narrowed_money_idents(line) {
            out.push(Finding::new(
                &f.path,
                idx,
                N1,
                format!(
                    "money value `{ident}` narrowed with `as f32`: the only sanctioned \
                     f64→f32 money edge is util::money::narrow — accumulate in f64 and \
                     narrow there"
                ),
            ));
        }
        // (c) money summed in f32
        if line.contains(".sum::<f32>()") && mentions_money(&statement_window(&f.code, idx)) {
            out.push(Finding::new(
                &f.path,
                idx,
                N1,
                "money summed with .sum::<f32>(): accumulate in f64 (`.sum::<f64>()`) and \
                 narrow once at the edge via util::money::narrow"
                    .to_string(),
            ));
        }
    }
}

/// Token rules (D1, D2, D3, N1) for one preprocessed file, before
/// allow suppression.
pub fn lint_file(f: &ScannedFile) -> Vec<Finding> {
    let mut out = Vec::new();
    if in_scope_d1(&f.path) {
        rule_d1(f, &mut out);
    }
    if in_scope_d2(&f.path) {
        rule_d2(f, &mut out);
    }
    rule_d3(f, &mut out);
    if f.path.starts_with("rust/src/") {
        rule_n1(f, &mut out);
    }
    out
}

// ------------------------------------------------------------------- S1

/// Extract `\"key\":` occurrences from one raw source line (the
/// emitters hand-roll JSON in string literals, so keys appear as
/// escaped quotes in the source text).
fn extract_json_keys(raw: &str, line: usize, out: &mut BTreeMap<String, usize>) {
    let b = raw.as_bytes();
    let mut i = 0;
    while i + 1 < b.len() {
        if b[i] == b'\\' && b[i + 1] == b'"' {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && is_ident_byte(b[j]) {
                j += 1;
            }
            if j > start
                && j + 2 < b.len()
                && b[j] == b'\\'
                && b[j + 1] == b'"'
                && b[j + 2] == b':'
            {
                out.entry(raw[start..j].to_string()).or_insert(line);
                i = j + 3;
                continue;
            }
            i = start;
        } else {
            i += 1;
        }
    }
}

/// Keys emitted by the explain emitters in `report/mod.rs`, with the
/// 0-based line each was first seen on.
pub fn emitted_explain_keys(report: &ScannedFile) -> BTreeMap<String, usize> {
    let mut keys = BTreeMap::new();
    let mut i = 0;
    while i < report.code.len() {
        let line = &report.code[i];
        if line.contains("fn explain_json") || line.contains("fn fleet_explain_json") {
            let end = body_end(&report.code, i);
            for k in i..=end {
                extract_json_keys(&report.raw[k], k, &mut keys);
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    keys
}

/// Parse the snapshot file: one key per line, `#` comments and blanks
/// ignored.
pub fn parse_key_snapshot(snapshot: &str) -> BTreeSet<String> {
    snapshot
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty() && !l.starts_with('#'))
        .map(str::to_string)
        .collect()
}

/// S1: diff emitted explain-v1 keys against the snapshot.
pub fn rule_s1(report: &ScannedFile, snapshot: &str, snapshot_path: &str) -> Vec<Finding> {
    let emitted = emitted_explain_keys(report);
    let mut out = Vec::new();
    if emitted.is_empty() {
        out.push(Finding {
            path: report.path.clone(),
            line: 0,
            rule: S1,
            message: "no explain emitters found (fn explain_json / fn fleet_explain_json*): \
                      S1 cannot verify the explain-v1 schema"
                .to_string(),
        });
        return out;
    }
    let pinned = parse_key_snapshot(snapshot);
    for (key, line) in &emitted {
        if !pinned.contains(key) {
            out.push(Finding::new(
                &report.path,
                *line,
                S1,
                format!(
                    "explain-v1 emits key \"{key}\" missing from {snapshot_path}: additions \
                     are fine but must update the snapshot in the same PR so the schema \
                     change is reviewable"
                ),
            ));
        }
    }
    for key in &pinned {
        if !emitted.contains_key(key) {
            out.push(Finding {
                path: snapshot_path.to_string(),
                line: 0,
                rule: S1,
                message: format!(
                    "explain-v1 key \"{key}\" is pinned in {snapshot_path} but no longer \
                     emitted: diagonal-scale/explain-v1 is additive-only — removals and \
                     renames break consumers"
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------------- S2

/// Metric-name consts (`pub const NAME: &str = "metric_name";`)
/// declared in `metrics/names.rs`, with the 0-based line each sits on.
/// Structure is matched on the blanked code (so the pattern cannot
/// fire inside comments or doc text) and the name itself is read from
/// the raw line, where string contents survive.
pub fn declared_metric_names(names: &ScannedFile) -> BTreeMap<String, usize> {
    let mut out = BTreeMap::new();
    for (idx, code) in names.code.iter().enumerate() {
        if !(has_token(code, "const") && code.contains(": &str")) {
            continue;
        }
        let raw = &names.raw[idx];
        let Some(eq) = raw.find('=') else { continue };
        let rest = &raw[eq + 1..];
        let Some(q1) = rest.find('"') else { continue };
        let Some(q2) = rest[q1 + 1..].find('"') else { continue };
        let name = &rest[q1 + 1..q1 + 1 + q2];
        if !name.is_empty() {
            out.entry(name.to_string()).or_insert(idx);
        }
    }
    out
}

/// S2: diff declared metrics-v1 names against the snapshot.
pub fn rule_s2(names: &ScannedFile, snapshot: &str, snapshot_path: &str) -> Vec<Finding> {
    let declared = declared_metric_names(names);
    let mut out = Vec::new();
    if declared.is_empty() {
        out.push(Finding {
            path: names.path.clone(),
            line: 0,
            rule: S2,
            message: "no metric-name consts found (`pub const NAME: &str = \"...\"`): S2 \
                      cannot verify the metrics-v1 name set"
                .to_string(),
        });
        return out;
    }
    let pinned = parse_key_snapshot(snapshot);
    for (name, line) in &declared {
        if !pinned.contains(name) {
            out.push(Finding::new(
                &names.path,
                *line,
                S2,
                format!(
                    "metrics-v1 declares \"{name}\" missing from {snapshot_path}: additions \
                     are fine but must update the snapshot in the same PR so the scrape \
                     surface changes in review"
                ),
            ));
        }
    }
    for name in &pinned {
        if !declared.contains_key(name) {
            out.push(Finding {
                path: snapshot_path.to_string(),
                line: 0,
                rule: S2,
                message: format!(
                    "metric \"{name}\" is pinned in {snapshot_path} but no longer declared: \
                     diagonal-scale/metrics-v1 is additive-only — removals and renames \
                     break dashboards and alerting rules"
                ),
            });
        }
    }
    out
}

// ------------------------------------------------------------------- T1

/// T1: reconcile `[[test]]`/`[[bench]]` path entries against the files
/// actually present in `rust/tests` / `rust/benches` (file names only,
/// e.g. `prop_fleet.rs`).
pub fn rule_t1(cargo_toml: &str, tests: &[String], benches: &[String]) -> Vec<Finding> {
    #[derive(PartialEq, Clone, Copy)]
    enum Section {
        Test,
        Bench,
        Other,
    }
    let mut section = Section::Other;
    // registered (file name -> 0-based line) per kind
    let mut reg_tests: BTreeMap<String, usize> = BTreeMap::new();
    let mut reg_benches: BTreeMap<String, usize> = BTreeMap::new();
    for (idx, line) in cargo_toml.lines().enumerate() {
        let t = line.trim();
        if t.starts_with("[[test]]") {
            section = Section::Test;
        } else if t.starts_with("[[bench]]") {
            section = Section::Bench;
        } else if t.starts_with('[') {
            section = Section::Other;
        } else if let Some(rest) = t.strip_prefix("path") {
            let rest = rest.trim_start();
            if let Some(v) = rest.strip_prefix('=') {
                let v = v.trim().trim_matches('"');
                let (dir, reg) = match section {
                    Section::Test => ("rust/tests/", &mut reg_tests),
                    Section::Bench => ("rust/benches/", &mut reg_benches),
                    Section::Other => continue,
                };
                if let Some(name) = v.strip_prefix(dir) {
                    reg.insert(name.to_string(), idx);
                }
            }
        }
    }
    let mut out = Vec::new();
    for (kind, dir, present, registered) in [
        ("[[test]]", "rust/tests", tests, &reg_tests),
        ("[[bench]]", "rust/benches", benches, &reg_benches),
    ] {
        for file in present {
            if !registered.contains_key(file) {
                out.push(Finding {
                    path: "Cargo.toml".to_string(),
                    line: 0,
                    rule: T1,
                    message: format!(
                        "{dir}/{file} has no {kind} path entry in Cargo.toml: auto-discovery \
                         is off (custom paths), so the target silently never runs"
                    ),
                });
            }
        }
        for (file, line) in registered {
            if !present.contains(file) {
                out.push(Finding::new(
                    "Cargo.toml",
                    *line,
                    T1,
                    format!(
                        "Cargo.toml registers {dir}/{file} as a {kind} target but the file \
                         does not exist"
                    ),
                ));
            }
        }
    }
    out
}

// --------------------------------------------------------------- driver

/// Whole-run result: findings after allow suppression, plus counters.
pub struct Report {
    /// Surviving findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    /// Source files scanned.
    pub files_scanned: usize,
    /// Inline allow directives present in the tree (justified or not).
    pub allow_directives: usize,
    /// Findings suppressed by a justified allow.
    pub suppressed: usize,
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.filter_map(|e| e.ok().map(|e| e.path())).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint the repository rooted at `root` (the directory holding
/// `Cargo.toml`, `rust/`, and `config/`).
pub fn lint_repo(root: &Path) -> std::io::Result<Report> {
    if !root.join("rust/src").is_dir() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            format!("{} does not look like the repo root (no rust/src)", root.display()),
        ));
    }
    let mut files = Vec::new();
    walk_rs(&root.join("rust/src"), &mut files)?;
    walk_rs(&root.join("rust/tests"), &mut files)?;
    walk_rs(&root.join("rust/benches"), &mut files)?;

    let mut findings = Vec::new();
    let mut suppressed = 0usize;
    let mut allow_directives = 0usize;
    let mut report_file: Option<ScannedFile> = None;
    let mut names_file: Option<ScannedFile> = None;
    let files_scanned = files.len();

    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let f = ScannedFile::parse(&rel(root, path), &src);
        allow_directives += f.allows.len();
        findings.extend(f.allow_findings());
        for finding in lint_file(&f) {
            if f.allowed(finding.line - 1, finding.rule) {
                suppressed += 1;
            } else {
                findings.push(finding);
            }
        }
        if f.path == "rust/src/report/mod.rs" {
            report_file = Some(f);
        } else if f.path == "rust/src/metrics/names.rs" {
            names_file = Some(f);
        }
    }

    // S1: emitted explain keys vs the checked-in snapshot
    let snapshot_path = "config/explain_v1.keys";
    match (&report_file, std::fs::read_to_string(root.join(snapshot_path))) {
        (Some(report), Ok(snapshot)) => {
            findings.extend(rule_s1(report, &snapshot, snapshot_path));
        }
        (Some(_), Err(_)) => findings.push(Finding {
            path: snapshot_path.to_string(),
            line: 0,
            rule: S1,
            message: "explain-v1 key snapshot is missing: regenerate it from the emitters \
                      in rust/src/report/mod.rs"
                .to_string(),
        }),
        (None, _) => findings.push(Finding {
            path: "rust/src/report/mod.rs".to_string(),
            line: 0,
            rule: S1,
            message: "rust/src/report/mod.rs not found: S1 cannot verify the explain-v1 \
                      schema"
                .to_string(),
        }),
    }

    // S2: declared metric names vs the checked-in snapshot. Unlike S1
    // the subsystem is optional: trees without a metrics registry have
    // neither the names module nor the snapshot, and that is fine —
    // only a one-sided state (one exists without the other) is a
    // finding.
    let names_snapshot_path = "config/metrics_v1.names";
    match (&names_file, std::fs::read_to_string(root.join(names_snapshot_path))) {
        (Some(names), Ok(snapshot)) => {
            findings.extend(rule_s2(names, &snapshot, names_snapshot_path));
        }
        (Some(_), Err(_)) => findings.push(Finding {
            path: names_snapshot_path.to_string(),
            line: 0,
            rule: S2,
            message: "metrics-v1 name snapshot is missing: regenerate it from the consts \
                      in rust/src/metrics/names.rs"
                .to_string(),
        }),
        (None, Ok(_)) => findings.push(Finding {
            path: "rust/src/metrics/names.rs".to_string(),
            line: 0,
            rule: S2,
            message: "config/metrics_v1.names exists but rust/src/metrics/names.rs does \
                      not: S2 cannot verify the metrics-v1 name set"
                .to_string(),
        }),
        (None, Err(_)) => {}
    }

    // T1: Cargo.toml registration vs files on disk
    let cargo = std::fs::read_to_string(root.join("Cargo.toml"))?;
    let list_names = |dir: &str| -> std::io::Result<Vec<String>> {
        let mut v = Vec::new();
        walk_rs(&root.join(dir), &mut v)?;
        Ok(v.iter()
            .filter_map(|p| p.file_name().map(|n| n.to_string_lossy().into_owned()))
            .collect())
    };
    findings.extend(rule_t1(&cargo, &list_names("rust/tests")?, &list_names("rust/benches")?));

    if allow_directives > MAX_ALLOWS {
        findings.push(Finding {
            path: "rust".to_string(),
            line: 0,
            rule: ALLOW_BUDGET,
            message: format!(
                "{allow_directives} inline simlint allows exceed the tree-wide budget of \
                 {MAX_ALLOWS}: fix findings instead of allowlisting them"
            ),
        });
    }

    findings.sort();
    Ok(Report { findings, files_scanned, allow_directives, suppressed })
}

// ----------------------------------------------------------------- json

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Render a [`Report`] as machine-readable JSON (hand-rolled: the tool
/// is dependency-free; schema `diagonal-scale/simlint-v1`).
pub fn to_json(report: &Report) -> String {
    let mut out = String::from("{\"schema\":\"diagonal-scale/simlint-v1\"");
    let _ = write!(
        out,
        ",\"files_scanned\":{},\"allow_directives\":{},\"suppressed\":{},\"clean\":{}",
        report.files_scanned,
        report.allow_directives,
        report.suppressed,
        report.findings.is_empty()
    );
    out.push_str(",\"rules\":[");
    for (i, (id, summary)) in RULES.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"id\":\"{}\",\"summary\":\"{}\"}}",
            json_escape(id),
            json_escape(summary)
        );
    }
    out.push_str("],\"findings\":[");
    for (i, f) in report.findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"rule\":\"{}\",\"path\":\"{}\",\"line\":{},\"message\":\"{}\"}}",
            json_escape(f.rule),
            json_escape(&f.path),
            f.line,
            json_escape(&f.message)
        );
    }
    out.push_str("]}");
    out
}

#[cfg(test)]
mod tests;
