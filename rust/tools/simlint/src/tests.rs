//! simlint self-tests: scanner unit tests, one firing + one passing
//! fixture per rule, lint_repo end-to-end on a synthetic tree, and
//! the real-tree gate (the repo itself must lint clean).

use super::*;

/// Run the token rules on one file and apply allow suppression the
/// same way `lint_repo` does. Returns (net findings, suppressed).
fn net_findings(path: &str, src: &str) -> (Vec<Finding>, usize) {
    let f = ScannedFile::parse(path, src);
    let mut out = f.allow_findings();
    let mut suppressed = 0;
    for finding in lint_file(&f) {
        if f.allowed(finding.line - 1, finding.rule) {
            suppressed += 1;
        } else {
            out.push(finding);
        }
    }
    (out, suppressed)
}

fn rules_of(findings: &[Finding]) -> Vec<&'static str> {
    findings.iter().map(|f| f.rule).collect()
}

// ------------------------------------------------------------- scanner

#[test]
fn strip_blanks_comments_and_strings() {
    let src =
        "let a = 1; // HashMap here\nlet s = \"HashMap\";\n/* HashMap\n HashMap */ let b = 2;";
    let code = strip_source(src);
    assert_eq!(code.len(), 4);
    assert!(!code.iter().any(|l| l.contains("HashMap")));
    assert!(code[0].contains("let a = 1;"));
    assert!(code[1].contains("let s = \"       \";"));
    assert!(code[3].contains("let b = 2;"));
}

#[test]
fn strip_handles_raw_strings_and_nesting() {
    let src = "let r = r#\"Instant::now \" still raw\"#; let x = 3;\n/* outer /* inner */ still comment */ let y = 4;";
    let code = strip_source(src);
    assert!(!code[0].contains("Instant"));
    assert!(code[0].contains("let x = 3;"));
    assert!(!code[1].contains("inner"));
    assert!(code[1].contains("let y = 4;"));
}

#[test]
fn strip_distinguishes_lifetimes_from_char_literals() {
    let src = "fn f<'a>(x: &'a str) -> char { let c = 'H'; let d = '\\n'; c.min(d) }";
    let code = strip_source(src);
    assert!(code[0].contains("fn f<'a>(x: &'a str)"));
    assert!(!code[0].contains("'H'"));
}

#[test]
fn strip_preserves_escaped_quote_in_string() {
    let src = "let s = \"he said \\\"hi\\\" loudly\"; let z = 5;";
    let code = strip_source(src);
    assert!(!code[0].contains("hi"));
    assert!(code[0].contains("let z = 5;"));
}

#[test]
fn token_matching_respects_ident_boundaries() {
    assert!(has_token("use std::collections::HashMap;", "HashMap"));
    assert!(has_token("let m: HashMap<u32, u32>;", "HashMap"));
    assert!(!has_token("let m = MyHashMapLike::new();", "HashMap"));
    assert!(!has_token("let hashmap = 1;", "HashMap"));
    assert!(has_token("std::time::Instant::now()", "Instant::now"));
}

#[test]
fn money_identifier_detection() {
    assert!(mentions_money("let total_cost = 1.0;"));
    assert!(mentions_money("spend_f64()"));
    assert!(!mentions_money("let cos = angle.cos();"));
    assert!(!mentions_money("let pending = 3;"));
}

#[test]
fn narrowing_walks_back_through_call_and_index_groups() {
    assert_eq!(narrowed_money_idents("self.spend_f64() as f32").len(), 1);
    assert_eq!(narrowed_money_idents("let x = spend as f32;").len(), 1);
    assert!(narrowed_money_idents("frac.max(0.0) as f32").is_empty());
    assert!(narrowed_money_idents("cs[0] as f32").is_empty());
    assert!(narrowed_money_idents("let y = count as f32;").is_empty());
}

// ------------------------------------------------------------ fixtures

#[test]
fn d1_fires_on_wall_clock() {
    let (f, _) = net_findings("rust/src/fleet/fixture.rs", include_str!("../fixtures/d1_fire.rs"));
    assert_eq!(rules_of(&f), vec![D1, D1, D1], "{f:?}");
}

#[test]
fn d1_passes_on_injected_clock() {
    let (f, _) = net_findings("rust/src/fleet/fixture.rs", include_str!("../fixtures/d1_pass.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn d1_skips_benchkit() {
    let (f, _) = net_findings("rust/src/benchkit/mod.rs", include_str!("../fixtures/d1_fire.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn d2_fires_on_hash_containers() {
    let (f, _) = net_findings("rust/src/policy/fixture.rs", include_str!("../fixtures/d2_fire.rs"));
    assert_eq!(rules_of(&f), vec![D2, D2, D2], "{f:?}");
}

#[test]
fn d2_passes_on_btree() {
    let (f, _) = net_findings("rust/src/policy/fixture.rs", include_str!("../fixtures/d2_pass.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn d2_skips_runtime_stub() {
    let (f, _) = net_findings("rust/src/runtime/mod.rs", include_str!("../fixtures/d2_fire.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn d3_fires_on_partial_order() {
    let (f, _) =
        net_findings("rust/src/cluster/fixture.rs", include_str!("../fixtures/d3_fire.rs"));
    assert_eq!(rules_of(&f), vec![D3, D3], "{f:?}");
    // the sort-key call and the impl signature, not the body's inner call
    assert!(f[0].message.contains("total_cmp"));
    assert!(f[1].message.contains("delegate"));
}

#[test]
fn d3_passes_on_total_cmp_delegation() {
    let (f, _) =
        net_findings("rust/src/cluster/fixture.rs", include_str!("../fixtures/d3_pass.rs"));
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn n1_fires_on_f32_money() {
    let (f, _) = net_findings("rust/src/fleet/fixture.rs", include_str!("../fixtures/n1_fire.rs"));
    assert_eq!(rules_of(&f), vec![N1, N1, N1], "{f:?}");
}

#[test]
fn n1_passes_on_f64_accumulation_with_allowed_edge() {
    let (f, suppressed) =
        net_findings("rust/src/util/money.rs", include_str!("../fixtures/n1_pass.rs"));
    assert!(f.is_empty(), "{f:?}");
    assert_eq!(suppressed, 1, "the sanctioned edge is allow-suppressed");
}

#[test]
fn s1_passes_on_matching_snapshot() {
    let report =
        ScannedFile::parse("rust/src/report/mod.rs", include_str!("../fixtures/s1_report.rs"));
    let f = rule_s1(&report, include_str!("../fixtures/s1_pass.keys"), "s1_pass.keys");
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn s1_fires_on_addition_and_removal() {
    let report =
        ScannedFile::parse("rust/src/report/mod.rs", include_str!("../fixtures/s1_report.rs"));
    let f = rule_s1(&report, include_str!("../fixtures/s1_fire.keys"), "s1_fire.keys");
    assert_eq!(rules_of(&f), vec![S1, S1], "{f:?}");
    let msgs = format!("{f:?}");
    assert!(msgs.contains("\\\"cost\\\"") && msgs.contains("missing from"), "{msgs}");
    assert!(msgs.contains("\\\"vanished\\\"") && msgs.contains("no longer emitted"), "{msgs}");
}

#[test]
fn s1_keys_only_from_emitters() {
    let report =
        ScannedFile::parse("rust/src/report/mod.rs", include_str!("../fixtures/s1_report.rs"));
    let keys: Vec<String> = emitted_explain_keys(&report).into_keys().collect();
    assert_eq!(keys, ["cost", "schema", "score", "tenant", "v"]);
    assert!(!keys.contains(&"unrelated".to_string()), "non-emitter keys excluded");
}

#[test]
fn s2_declared_names_ignore_decoys() {
    let names =
        ScannedFile::parse("rust/src/metrics/names.rs", include_str!("../fixtures/s2_names.rs"));
    let declared: Vec<String> = declared_metric_names(&names).into_keys().collect();
    assert_eq!(declared, ["arbiter_budget_hourly", "fleet_spend_hourly", "fleet_ticks_total"]);
}

#[test]
fn s2_passes_on_matching_snapshot() {
    let names =
        ScannedFile::parse("rust/src/metrics/names.rs", include_str!("../fixtures/s2_names.rs"));
    let f = rule_s2(&names, include_str!("../fixtures/s2_pass.names"), "s2_pass.names");
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn s2_fires_on_addition_and_removal() {
    let names =
        ScannedFile::parse("rust/src/metrics/names.rs", include_str!("../fixtures/s2_names.rs"));
    let f = rule_s2(&names, include_str!("../fixtures/s2_fire.names"), "s2_fire.names");
    assert_eq!(rules_of(&f), vec![S2, S2], "{f:?}");
    let msgs = format!("{f:?}");
    assert!(msgs.contains("fleet_spend_hourly") && msgs.contains("missing from"), "{msgs}");
    assert!(msgs.contains("vanished_metric") && msgs.contains("no longer declared"), "{msgs}");
}

#[test]
fn t1_passes_on_reconciled_manifest() {
    let f = rule_t1(
        include_str!("../fixtures/t1_pass.toml"),
        &["alpha.rs".to_string()],
        &["beta.rs".to_string()],
    );
    assert!(f.is_empty(), "{f:?}");
}

#[test]
fn t1_fires_on_orphans_and_ghosts() {
    let f = rule_t1(
        include_str!("../fixtures/t1_fire.toml"),
        &["alpha.rs".to_string(), "orphan.rs".to_string()],
        &["beta.rs".to_string(), "stray.rs".to_string()],
    );
    assert_eq!(rules_of(&f), vec![T1, T1, T1], "{f:?}");
    let msgs = format!("{f:?}");
    assert!(msgs.contains("orphan.rs") && msgs.contains("stray.rs"), "{msgs}");
    assert!(msgs.contains("ghost.rs") && msgs.contains("does not exist"), "{msgs}");
}

// --------------------------------------------------------------- allows

#[test]
fn allow_requires_justification() {
    let src = "pub fn f() -> f32 {\n    // simlint: allow(n1-money-in-f64)\n    spend as f32\n}\n";
    let (f, suppressed) = net_findings("rust/src/fixture.rs", src);
    // unjustified: the directive itself fires AND the finding survives
    assert_eq!(suppressed, 0);
    assert_eq!(rules_of(&f), vec![ALLOW, N1], "{f:?}");
}

#[test]
fn allow_with_unknown_rule_fires() {
    let src = "// simlint: allow(zz-bogus): because.\npub fn f() {}\n";
    let (f, _) = net_findings("rust/src/fixture.rs", src);
    assert_eq!(rules_of(&f), vec![ALLOW], "{f:?}");
    assert!(f[0].message.contains("unknown rule id"));
}

#[test]
fn allow_only_covers_adjacent_line() {
    let src = "// simlint: allow(n1-money-in-f64): too far away.\n\n\npub fn f(spend: f64) -> f32 {\n    spend as f32\n}\n";
    let (f, suppressed) = net_findings("rust/src/fixture.rs", src);
    assert_eq!(suppressed, 0);
    assert_eq!(rules_of(&f), vec![N1], "{f:?}");
}

#[test]
fn allow_for_wrong_rule_does_not_suppress() {
    let src = "pub fn f(spend: f64) -> f32 {\n    // simlint: allow(d1-no-wall-clock): wrong rule.\n    spend as f32\n}\n";
    let (f, suppressed) = net_findings("rust/src/fixture.rs", src);
    assert_eq!(suppressed, 0);
    assert_eq!(rules_of(&f), vec![N1], "{f:?}");
}

// ------------------------------------------------- lint_repo end-to-end

struct TempTree(PathBuf);

impl TempTree {
    fn new(tag: &str) -> Self {
        let root =
            std::env::temp_dir().join(format!("simlint_{}_{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(root.join("rust/src/report")).unwrap();
        std::fs::create_dir_all(root.join("config")).unwrap();
        Self(root)
    }

    fn write(&self, rel: &str, content: &str) {
        let p = self.0.join(rel);
        std::fs::create_dir_all(p.parent().unwrap()).unwrap();
        std::fs::write(p, content).unwrap();
    }
}

impl Drop for TempTree {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

const MINI_REPORT: &str =
    "pub fn explain_json(v: u32) -> String {\n    format!(\"{{\\\"v\\\":{v}}}\")\n}\n";

fn mini_tree(tag: &str) -> TempTree {
    let t = TempTree::new(tag);
    t.write("rust/src/report/mod.rs", MINI_REPORT);
    t.write("config/explain_v1.keys", "v\n");
    t.write("Cargo.toml", "[package]\nname = \"demo\"\n");
    t
}

#[test]
fn lint_repo_clean_on_minimal_tree() {
    let t = mini_tree("clean");
    let report = lint_repo(&t.0).unwrap();
    assert!(report.findings.is_empty(), "{:?}", report.findings);
    assert_eq!(report.files_scanned, 1);
}

#[test]
fn lint_repo_enforces_allow_budget() {
    let t = mini_tree("budget");
    let mut src = String::from("pub fn f() {}\n");
    for i in 0..(MAX_ALLOWS + 1) {
        src.push_str(&format!(
            "// simlint: allow(d1-no-wall-clock): budget filler {i}.\n"
        ));
    }
    t.write("rust/src/lib.rs", &src);
    let report = lint_repo(&t.0).unwrap();
    assert_eq!(rules_of(&report.findings), vec![ALLOW_BUDGET], "{:?}", report.findings);
    assert_eq!(report.allow_directives, MAX_ALLOWS + 1);
}

#[test]
fn lint_repo_flags_missing_snapshot() {
    let t = mini_tree("nosnap");
    std::fs::remove_file(t.0.join("config/explain_v1.keys")).unwrap();
    let report = lint_repo(&t.0).unwrap();
    assert_eq!(rules_of(&report.findings), vec![S1], "{:?}", report.findings);
}

#[test]
fn lint_repo_skips_s2_when_tree_has_no_metrics_registry() {
    // mini_tree has neither metrics/names.rs nor the snapshot: S2 is
    // simply not applicable (covered by lint_repo_clean_on_minimal_tree
    // staying clean); a one-sided state, however, is a finding...
    let t = mini_tree("s2side");
    t.write("config/metrics_v1.names", "fleet_ticks_total\n");
    let report = lint_repo(&t.0).unwrap();
    assert_eq!(rules_of(&report.findings), vec![S2], "{:?}", report.findings);
    // ...and adding the matching names module makes the gate clean again
    t.write(
        "rust/src/metrics/names.rs",
        "pub const FLEET_TICKS_TOTAL: &str = \"fleet_ticks_total\";\n",
    );
    let report = lint_repo(&t.0).unwrap();
    assert!(report.findings.is_empty(), "{:?}", report.findings);
}

#[test]
fn json_output_is_well_formed() {
    let t = mini_tree("json");
    t.write("rust/src/bad.rs", "pub fn f() { let _ = std::time::Instant::now(); }\n");
    let report = lint_repo(&t.0).unwrap();
    let json = to_json(&report);
    assert!(json.starts_with("{\"schema\":\"diagonal-scale/simlint-v1\""));
    assert!(json.contains("\"clean\":false"));
    assert!(json.contains("\"rule\":\"d1-no-wall-clock\""));
    assert!(json.contains("\"path\":\"rust/src/bad.rs\""));
    // every quote inside messages must be escaped: a raw parse sanity
    // check without a JSON dependency — balanced braces and no bare
    // control characters.
    assert!(!json.chars().any(|c| (c as u32) < 0x20));
}

// ------------------------------------------------------ real-tree gate

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../..").canonicalize().unwrap()
}

#[test]
fn real_tree_lints_clean() {
    let report = lint_repo(&repo_root()).unwrap();
    let rendered: Vec<String> = report
        .findings
        .iter()
        .map(|f| format!("{}:{} [{}] {}", f.path, f.line, f.rule, f.message))
        .collect();
    assert!(report.findings.is_empty(), "repo must lint clean:\n{}", rendered.join("\n"));
    assert!(
        report.allow_directives <= MAX_ALLOWS,
        "allow budget: {} > {}",
        report.allow_directives,
        MAX_ALLOWS
    );
    assert!(report.files_scanned > 30, "expected to scan the real tree");
}

#[test]
fn real_tree_truncated_snapshot_fails_s1() {
    let root = repo_root();
    let report_src = std::fs::read_to_string(root.join("rust/src/report/mod.rs")).unwrap();
    let report = ScannedFile::parse("rust/src/report/mod.rs", &report_src);
    let snapshot = std::fs::read_to_string(root.join("config/explain_v1.keys")).unwrap();
    let keys: Vec<&str> = snapshot
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .collect();
    assert!(keys.len() > 10, "real snapshot should pin a substantial key set");
    // drop the last key: simlint must flag the unreviewed addition
    let truncated = keys[..keys.len() - 1].join("\n");
    let f = rule_s1(&report, &truncated, "config/explain_v1.keys");
    assert!(
        f.iter().any(|x| x.rule == S1 && x.message.contains("missing from")),
        "deleting a pinned key must fail the gate: {f:?}"
    );
}

#[test]
fn real_tree_truncated_metrics_snapshot_fails_s2() {
    let root = repo_root();
    let names_src = std::fs::read_to_string(root.join("rust/src/metrics/names.rs")).unwrap();
    let names = ScannedFile::parse("rust/src/metrics/names.rs", &names_src);
    let snapshot = std::fs::read_to_string(root.join("config/metrics_v1.names")).unwrap();
    let pinned: Vec<&str> = snapshot
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .collect();
    assert!(pinned.len() > 30, "real snapshot should pin a substantial name set");
    assert!(rule_s2(&names, &snapshot, "config/metrics_v1.names").is_empty());
    // drop the last name: simlint must flag the unreviewed addition
    let truncated = pinned[..pinned.len() - 1].join("\n");
    let f = rule_s2(&names, &truncated, "config/metrics_v1.names");
    assert!(
        f.iter().any(|x| x.rule == S2 && x.message.contains("missing from")),
        "deleting a pinned name must fail the gate: {f:?}"
    );
}

#[test]
fn real_tree_unregistered_test_fails_t1() {
    let root = repo_root();
    let cargo = std::fs::read_to_string(root.join("Cargo.toml")).unwrap();
    let mut tests: Vec<String> = std::fs::read_dir(root.join("rust/tests"))
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .filter(|n| n.ends_with(".rs"))
        .collect();
    tests.sort();
    let benches: Vec<String> = std::fs::read_dir(root.join("rust/benches"))
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.file_name().to_string_lossy().into_owned()))
        .filter(|n| n.ends_with(".rs"))
        .collect();
    assert!(rule_t1(&cargo, &tests, &benches).is_empty(), "real manifest reconciles");
    // dropping an unregistered file into rust/tests must fail the gate
    tests.push("zz_unregistered.rs".to_string());
    let f = rule_t1(&cargo, &tests, &benches);
    assert!(
        f.iter().any(|x| x.rule == T1 && x.message.contains("zz_unregistered.rs")),
        "{f:?}"
    );
}
