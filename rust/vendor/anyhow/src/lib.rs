//! Offline stand-in for the `anyhow` crate: the subset this workspace
//! actually uses — [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream where it matters to callers:
//!
//! * `{}` displays the outermost message only; `{:#}` joins the whole
//!   context chain with `": "` (the format the tests grep).
//! * `?` converts any `std::error::Error + Send + Sync + 'static` via
//!   the blanket `From` impl (source chain preserved as text).
//! * `.context(..)` / `.with_context(..)` work on `Result<T, E>` for
//!   std errors, on `Result<T, Error>`, and on `Option<T>`.
//!
//! Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that keeps the blanket `From` impl coherent.

use std::fmt;

/// An error wrapping a message plus its context chain (outermost
/// context first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// The whole context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        for cause in &self.chain[1..] {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Marker for the `Result<T, Error>` impl of [`Context`] — keeps it
/// coherently disjoint from the std-error blanket impl (the `E` slot
/// never unifies with [`Error`]'s).
pub enum ChainMarker {}

impl<T> Context<T, ChainMarker> for Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_shows_outer_alternate_shows_chain() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn context_on_std_result() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert!(format!("{e:#}").contains("outer"));
        assert!(format!("{e:#}").contains("gone"));
    }

    #[test]
    fn context_on_anyhow_result_and_option() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        let n: Option<u32> = None;
        assert!(n.context("missing").is_err());
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert!(f(5).is_err());
        assert!(format!("{:#}", f(12).unwrap_err()).contains("too big"));
    }
}
