//! Offline stub of the XLA/PJRT binding surface that
//! `diagonal_scale::runtime` compiles against.
//!
//! The real bindings wrap a PJRT plugin (CPU/TPU); this stub carries
//! the same type and method signatures but [`PjRtClient::cpu`] returns
//! an error, so every artifact-backed path fails fast with a clear
//! message while the native rust surface backend — the default for the
//! simulator, cluster coordinator, and fleet — is unaffected.
//! Host-side [`Literal`] plumbing is implemented for real so shape code
//! stays exercised.

use std::fmt;
use std::path::Path;

/// Stub error type (Display-able, like the real binding's error).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT runtime unavailable — this build links the offline XLA API stub \
         (no PJRT plugin); native surface backends are unaffected"
    )))
}

/// Element types a [`Literal`] can be read back as.
pub trait Element: Sized + Copy {
    fn from_f32(x: f32) -> Self;
}

impl Element for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

/// Host-side literal: flat f32 storage plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Self {
        Self { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Self { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Decompose a tuple literal (only produced by executions, which the
    /// stub cannot perform).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Parsed HLO module (stub: parsing requires the real binding).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let p = path.as_ref();
        std::fs::read_to_string(p)
            .map_err(|e| Error(format!("reading {}: {e}", p.display())))?;
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self
    }
}

/// A device-resident buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<A>(&self, _args: &[A]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// A PJRT client.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().map(|_| ()).unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"));
    }
}
