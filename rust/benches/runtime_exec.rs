//! Runtime benches — the PJRT hot path (EXPERIMENTS.md §Perf):
//! artifact execution latency for each entry point, against the native
//! rust equivalents, plus amortization of the full-trace kernel.
//!
//! ```text
//! make artifacts && cargo bench --bench runtime_exec
//! ```

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::{ModelConfig, MoveFlags};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::TraceBuilder;

fn main() {
    let cfg = ModelConfig::default_paper();
    let artifacts = Engine::default_dir();
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let eng = SurfaceEngine::new(Engine::load(&artifacts).unwrap(), &cfg).unwrap();
    let model = SurfaceModel::from_config(&cfg);
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let b = Bench::default();
    let lambda = 10_000.0f32;

    group("PJRT entry-point execution latency");
    b.run("pjrt/surfaces_grid", || eng.surfaces(lambda).unwrap().latency[0]);
    b.run("pjrt/queueing_grid", || eng.queueing(lambda).unwrap().0[0]);
    let cand = vec![0.5f32; 16 * 16];
    b.run("pjrt/neighbor_scores", || {
        eng.neighbor_scores(&cand, lambda, MoveFlags::DIAGONAL).unwrap().0[0]
    });
    let trace_stats = b.run("pjrt/policy_trace_50 (whole sim in XLA)", || {
        eng.policy_trace(&trace, MoveFlags::DIAGONAL, (1, 1)).unwrap().len()
    });
    b.report_metric(
        "pjrt/policy_trace_50 per-step cost",
        trace_stats.mean.as_secs_f64() * 1e9 / 50.0,
        "ns/step",
    );

    group("native equivalents (for the crossover analysis)");
    b.run("native/surfaces_grid", || model.evaluate_grid(lambda).len());
    let native_stats = b.run("native/phase1_sim_50_steps", || {
        sim.run(PolicyKind::Diagonal, &trace).summary.violations
    });
    b.report_metric(
        "native/phase1_sim per-step cost",
        native_stats.mean.as_secs_f64() * 1e9 / 50.0,
        "ns/step",
    );

    println!(
        "\nnote: on a 4x4 plane the native path wins on absolute latency — the\n\
         PJRT path pays per-call dispatch (~tens of us) that a TPU-resident\n\
         deployment amortizes by batching whole traces (policy_trace) or many\n\
         tenants into one executable launch. See EXPERIMENTS.md §Perf."
    );
}
