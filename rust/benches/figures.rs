//! Benches F1–F4 — regenerate the static surface figures:
//!   fig 1  cost heatmap          fig 2  latency heatmap
//!   fig 3  3-D latency surface   fig 4  objective heatmap
//! and time their generation (native vs PJRT-executed kernel when
//! artifacts exist).
//!
//! ```text
//! cargo bench --bench figures
//! ```

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::report::{self, Surface};
use diagonal_scale::runtime::{Engine, SurfaceEngine};
use diagonal_scale::surfaces::SurfaceModel;

fn main() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let b = Bench::default();
    let lambda = 10_000.0;

    std::fs::create_dir_all("out").ok();
    for (fig, surface, file) in [
        ("fig1", Surface::Cost, "out/fig1_cost_heatmap.csv"),
        ("fig2", Surface::Latency, "out/fig2_latency_heatmap.csv"),
        ("fig4", Surface::Objective, "out/fig4_objective_heatmap.csv"),
    ] {
        group(&format!("{fig} — {} heatmap over the Scaling Plane", surface.name()));
        let csv = report::heatmap_csv(&model, surface, lambda);
        std::fs::write(file, &csv).unwrap();
        println!("{csv}");
        b.run(&format!("{fig}_heatmap_generation"), || {
            report::heatmap_csv(&model, surface, lambda).len()
        });
    }

    group("fig3 — 3-D latency surface (long form)");
    let csv = report::surface_csv(&model, Surface::Latency, lambda);
    std::fs::write("out/fig3_latency_surface.csv", &csv).unwrap();
    println!("{csv}");
    b.run("fig3_surface_generation", || {
        report::surface_csv(&model, Surface::Latency, lambda).len()
    });

    group("surface evaluation — native vs AOT Pallas kernel on PJRT");
    b.run("native_grid_evaluation_16_configs", || {
        model.evaluate_grid(lambda).len()
    });
    let artifacts = Engine::default_dir();
    if artifacts.join("manifest.json").exists() {
        let eng = SurfaceEngine::new(Engine::load(&artifacts).unwrap(), &cfg).unwrap();
        b.run("pjrt_grid_evaluation_16_configs", || {
            eng.surfaces(lambda).unwrap().latency[0]
        });
    } else {
        println!("(run `make artifacts` for the PJRT comparison)");
    }
}
