//! Bench A6 — the paper's §IV.F complexity claim: the decision loop
//! evaluates at most nine closed-form candidates, O(1) per step, and is
//! "suitable for a real-time control loop".
//!
//! ```text
//! cargo bench --bench decision_latency
//! ```

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::plane::Configuration;
use diagonal_scale::policy::{DiagonalScale, Lookahead, Oracle, Policy, PolicyContext};
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::sla::SlaSpec;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::{TraceBuilder, WorkloadPoint};

fn main() {
    let cfg = ModelConfig::default_paper();
    let model = SurfaceModel::from_config(&cfg);
    let sla = SlaSpec::from_config(&cfg);
    let ctx = PolicyContext {
        model: &model,
        sla: &sla,
        reb_h: cfg.policy.reb_h,
        reb_v: cfg.policy.reb_v,
        plan_queue: false,
        future: &[],
        budget: None,
    };
    let b = Bench::default();
    let w = WorkloadPoint::new(10_000.0, cfg.write_ratio());

    group("A6 — single-decision latency (paper IV.F: O(|N|) = O(1))");
    // interior (9 candidates) vs corner (4 candidates): both must be
    // sub-microsecond and within a small constant factor
    let interior = b.run("decide/interior_9_candidates", || {
        DiagonalScale::diagonal().decide(Configuration::new(1, 1), w, &ctx)
    });
    let corner = b.run("decide/corner_4_candidates", || {
        DiagonalScale::diagonal().decide(Configuration::new(0, 0), w, &ctx)
    });
    let ratio = interior.mean.as_secs_f64() / corner.mean.as_secs_f64().max(1e-12);
    b.report_metric("interior/corner time ratio (O(1) check)", ratio, "x");

    b.run("decide/oracle_full_plane_16", || {
        Oracle.decide(Configuration::new(1, 1), w, &ctx)
    });
    let future = [w; 3];
    let ctx_f = PolicyContext { future: &future, ..ctx };
    for depth in [2usize, 3] {
        b.run(&format!("decide/lookahead_depth_{depth}"), || {
            Lookahead::new(diagonal_scale::config::MoveFlags::DIAGONAL, depth)
                .decide(Configuration::new(1, 1), w, &ctx_f)
        });
    }

    group("A6 — full control-loop step (simulate + decide)");
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let stats = b.run("phase1_sim/50_steps_diagonal", || {
        sim.run(PolicyKind::Diagonal, &trace).summary.violations
    });
    b.report_metric(
        "per-step cost within the full loop",
        stats.mean.as_secs_f64() * 1e9 / 50.0,
        "ns/step",
    );
}
