//! Bench P — placement at fleet scale: packed (shared clusters) vs
//! dedicated (one cluster per tenant) as the tenant count sweeps
//! 4 → 64, on the staggered small-tenant scenario.
//!
//! ```text
//! cargo bench --bench placement
//! ```
//!
//! Reports per-mode tick wall time (the packer replans every 4 ticks,
//! so the amortized cost of FFD + local search is included) and the
//! cost ratio packed/dedicated over a full trace cycle — the number
//! the tentpole exists for.

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::placement::{small_tenant_specs, PlacementConfig, PlacementSim};

const BUDGET: f32 = 1.0e9;
const K: usize = 3;

fn main() {
    let cfg = ModelConfig::default_paper();
    let pcfg = PlacementConfig::default();
    let b = Bench::quick();

    group("placement tick wall time — packed vs dedicated vs tenant count");
    for n in [4usize, 8, 16, 32, 64] {
        let mut packed =
            PlacementSim::packed(&cfg, small_tenant_specs(&cfg, n, 0.1), BUDGET, K, pcfg);
        packed.set_recording(false);
        let ps = b.run(&format!("placement_tick/packed/{n:>2}_tenants"), || {
            packed.tick().admitted_moves
        });
        let mut dedicated =
            PlacementSim::dedicated(&cfg, small_tenant_specs(&cfg, n, 0.1), BUDGET, K, pcfg);
        dedicated.set_recording(false);
        let ds = b.run(&format!("placement_tick/dedicated/{n:>2}_tenants"), || {
            dedicated.tick().admitted_moves
        });
        b.report_metric(
            &format!("packed/dedicated tick-time ratio at {n} tenants"),
            ps.mean.as_secs_f64() / ds.mean.as_secs_f64().max(1e-12),
            "x",
        );
    }

    group("fleet cost over one trace cycle — packed vs dedicated");
    let steps = 50;
    for n in [4usize, 8, 16, 32, 64] {
        let mut packed =
            PlacementSim::packed(&cfg, small_tenant_specs(&cfg, n, 0.1), BUDGET, K, pcfg);
        packed.set_recording(false);
        let pk = packed.run(steps);
        let mut dedicated =
            PlacementSim::dedicated(&cfg, small_tenant_specs(&cfg, n, 0.1), BUDGET, K, pcfg);
        dedicated.set_recording(false);
        let ded = dedicated.run(steps);
        b.report_metric(
            &format!("cost ratio packed/dedicated at {n:>2} tenants"),
            pk.total_cost() / ded.total_cost().max(1e-9),
            "x",
        );
        b.report_metric(
            &format!("migrations at {n:>2} tenants"),
            pk.total_migrations() as f64,
            "moves",
        );
        if pk.total_violations() > ded.total_violations() {
            println!(
                "note: packed violated more than dedicated at {n} tenants ({} vs {})",
                pk.total_violations(),
                ded.total_violations()
            );
        }
    }
}
