//! Bench M — observability-layer cost: per-op cost of the three
//! sketches (HLL insert/estimate, streaming push vs exact-recorder
//! push, registry render), so "metrics are O(1) and cheap" is a
//! measured claim, not an assumed one.
//!
//! ```text
//! cargo bench --bench metrics
//! ```

// Benches measure wall time by design; decision code is covered by
// simlint's d1-no-wall-clock + clippy's disallowed_methods instead.
#![allow(clippy::disallowed_methods)]

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::metrics::hll::Hll;
use diagonal_scale::metrics::{names, MetricsRegistry, Recorder, StepRecord, StreamingRecorder};
use diagonal_scale::plane::Configuration;
use diagonal_scale::sla::Violation;
use diagonal_scale::workload::XorShift64;

fn record(step: usize, latency: f32) -> StepRecord {
    StepRecord {
        step,
        config: Configuration::new(1, 1),
        lambda_req: 1000.0,
        latency,
        latency_raw: latency * 0.9,
        throughput: 2000.0,
        cost: 1.0,
        objective: 0.1,
        violation: Violation::default(),
    }
}

fn main() {
    let b = Bench::default();

    group("hyperloglog — insert and estimate cost (p=10, 1 KiB dense)");
    {
        let mut sketch = Hll::default();
        let mut rng = XorShift64::new(7);
        b.run("hll_insert_u64", || {
            sketch.insert_u64(rng.next_u64());
            sketch.m()
        });
        let stats = b.run("hll_estimate", || sketch.estimate());
        b.report_metric("hll_estimate", stats.mean.as_secs_f64() * 1e9, "ns/call");
        b.report_metric("hll memory", sketch.m() as f64, "registers (1 B each)");
    }

    group("recorder push — exact (grows) vs streaming (O(1) memory)");
    {
        let mut rng = XorShift64::new(11);
        let mut exact = Recorder::new();
        let mut step = 0usize;
        let e = b.run("recorder_push/exact", || {
            step += 1;
            exact.push(record(step, (rng.next_f64() * 0.05) as f32));
            exact.len()
        });
        let mut stream = StreamingRecorder::new(32, 0x5EED);
        let mut sstep = 0usize;
        let s = b.run("recorder_push/streaming", || {
            sstep += 1;
            stream.push(record(sstep, (rng.next_f64() * 0.05) as f32));
            stream.retained()
        });
        b.report_metric(
            "streaming/exact push-cost ratio",
            s.mean.as_secs_f64() / e.mean.as_secs_f64().max(1e-12),
            "x",
        );
        b.report_metric("exact retained after sweep", exact.len() as f64, "records");
        b.report_metric("streaming retained after sweep", stream.retained() as f64, "records");
    }

    group("registry — full exposition render (39 declared families)");
    {
        let mut reg = MetricsRegistry::new();
        reg.declare_all();
        let mut rng = XorShift64::new(13);
        for i in 0..10_000u64 {
            reg.inc(names::FLEET_TICKS_TOTAL, &[], 1);
            reg.set(names::FLEET_SPEND_HOURLY, &[], i as f64);
            reg.observe(
                names::FLEET_PLANNING_SECONDS,
                &[],
                names::PLANNING_FLOOR,
                rng.next_f64() * 1e-3,
            );
        }
        let p = b.run("render_prometheus", || reg.render_prometheus().len());
        let j = b.run("render_json", || reg.render_json().len());
        b.report_metric("render_prometheus", p.mean.as_secs_f64() * 1e6, "us/render");
        b.report_metric("render_json", j.mean.as_secs_f64() * 1e6, "us/render");
    }
}
