//! Bench C — substrate engines head-to-head: steps/sec (and ops/sec)
//! for the event-driven engine vs the legacy per-op sampling engine,
//! swept over cluster size H ∈ {2..64} at paper-peak load and over
//! offered load at a fixed H.
//!
//! ```text
//! cargo bench --bench cluster
//! ```
//!
//! The sampling engine runs with thinning disabled
//! (`max_ops_per_step = usize::MAX`) so both engines simulate every
//! arrival — the honest comparison. The acceptance bar for the event
//! engine is ≥ 5x at H=32 under paper-peak load (16k ops/interval).

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::cluster::{ClusterParams, ClusterSim, EventSim, Substrate};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::plane::Configuration;
use diagonal_scale::workload::WorkloadPoint;

/// Paper-peak offered load (ops per interval).
const PEAK: f32 = 16_000.0;

/// A plane whose H axis reaches 64 nodes (the default paper plane
/// stops at 8); tiers are unchanged.
fn wide_cfg() -> ModelConfig {
    let mut cfg = ModelConfig::default_paper();
    cfg.plane.h_values = vec![2, 4, 8, 16, 32, 64];
    cfg.policy.start = [0, 1];
    cfg.validate().expect("bench plane must validate");
    cfg
}

fn params() -> ClusterParams {
    // disable thinning so the sampling engine does the same physical
    // work per offered op as the event engine
    ClusterParams { max_ops_per_step: usize::MAX, ..ClusterParams::default() }
}

/// Settle a substrate at the given H index: apply, then burn past the
/// rebalance window at negligible load.
fn settle<S: Substrate>(sub: &mut S, h_idx: usize) {
    sub.apply(Configuration::new(h_idx, 1));
    for _ in 0..3 {
        sub.step(WorkloadPoint::new(100.0, 0.3));
    }
}

fn bench_steps<S: Substrate>(b: &Bench, name: &str, sub: &mut S, lambda: f32) -> f64 {
    let w = WorkloadPoint::new(lambda, 0.3);
    let stats = b.run(name, || sub.step(w).completed);
    let mean = stats.mean.as_secs_f64();
    b.report_metric(
        &format!("{name} throughput"),
        lambda as f64 / mean,
        "sim-ops/s",
    );
    mean
}

fn main() {
    let cfg = wide_cfg();
    let b = Bench::default();
    let bq = Bench::quick();

    group("substrate step cost vs cluster size H (paper-peak load, 16k ops/interval)");
    let mut at_h32: Option<(f64, f64)> = None;
    for (h_idx, h) in [2usize, 4, 8, 16, 32, 64].into_iter().enumerate() {
        let mut sampling = ClusterSim::new(&cfg, params(), 42);
        settle(&mut sampling, h_idx);
        let t_sampling =
            bench_steps(&b, &format!("sampling/H={h:>2}"), &mut sampling, PEAK);

        let mut event = EventSim::new(&cfg, params(), 42);
        settle(&mut event, h_idx);
        let t_event = bench_steps(&b, &format!("event   /H={h:>2}"), &mut event, PEAK);

        b.report_metric(
            &format!("event-engine speedup at H={h}"),
            t_sampling / t_event,
            "x",
        );
        if h == 32 {
            at_h32 = Some((t_sampling, t_event));
        }
    }

    group("substrate step cost vs offered load (H=8)");
    for lambda in [2_000.0f32, 8_000.0, 16_000.0, 32_000.0, 64_000.0] {
        let mut sampling = ClusterSim::new(&cfg, params(), 42);
        settle(&mut sampling, 2);
        let t_sampling = bench_steps(
            &bq,
            &format!("sampling/lambda={:>5}", lambda as u32),
            &mut sampling,
            lambda,
        );

        let mut event = EventSim::new(&cfg, params(), 42);
        settle(&mut event, 2);
        let t_event = bench_steps(
            &bq,
            &format!("event   /lambda={:>5}", lambda as u32),
            &mut event,
            lambda,
        );
        b.report_metric(
            &format!("event-engine speedup at lambda={}", lambda as u32),
            t_sampling / t_event,
            "x",
        );
    }

    group("acceptance: event engine vs sampling at H=32, paper-peak load");
    let (ts, te) = at_h32.expect("H=32 measured");
    let speedup = ts / te;
    println!(
        "event engine is {speedup:.1}x the sampling path at H=32 under paper-peak load \
         (target >= 5x): {}",
        if speedup >= 5.0 { "PASS" } else { "MISS — investigate" }
    );
}
