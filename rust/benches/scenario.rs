//! Bench N — scenario-generation cost and preset sweep: how much a
//! named scenario costs to *materialize* (trace synthesis, shard-map
//! generation, fault scheduling) versus to *run*, so the scenario
//! subsystem's "generation is cheap, simulation dominates" claim is a
//! measured number per preset rather than folklore.
//!
//! ```text
//! cargo bench --bench scenario
//! ```

// Benches measure wall time by design; decision code is covered by
// simlint's d1-no-wall-clock + clippy's disallowed_methods instead.
#![allow(clippy::disallowed_methods)]

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::cluster::{ClusterParams, SubstrateKind};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{BudgetArbiter, ClassEnvelopes, FleetSimulator, ForecastKind};
use diagonal_scale::placement::{PlacementConfig, PlacementSim};
use diagonal_scale::scenario::{self, DEFAULT_SEED};

fn main() {
    let b = Bench::quick();
    let cfg = ModelConfig::default_paper();

    group("materialization — preset -> specs + faults + shard map");
    for name in scenario::PRESETS {
        let stats = b.run(&format!("materialize/{name}"), || {
            let sc = scenario::preset(name, &cfg, 12, DEFAULT_SEED).expect("known preset");
            sc.specs.len() + sc.faults.len()
        });
        b.report_metric(
            &format!("materialize/{name}"),
            stats.mean.as_secs_f64() * 1e6,
            "us/preset",
        );
    }

    group("fleet sweep — planning arbiter over every preset horizon");
    for name in scenario::PRESETS {
        let sc = scenario::preset(name, &cfg, 6, DEFAULT_SEED).expect("known preset");
        b.run(&format!("fleet/{name}"), || {
            let arb = BudgetArbiter::new(8.0, 3).with_envelopes(ClassEnvelopes::default_split());
            let mut sim = FleetSimulator::with_arbiter(&cfg, sc.specs.clone(), arb);
            sim.enable_forecasts(ForecastKind::Seasonal, 3);
            if !sc.faults.is_empty() {
                sim.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
                let accepted = sim.schedule_faults(&sc.faults, ClusterParams::default().interval);
                sim.set_scenario(sc.name, accepted);
            }
            sim.run(sc.steps).total_violations()
        });
    }

    group("placement — heavy-tail packed vs dedicated, shard-priced moves");
    {
        let sc = scenario::preset("heavy-tail", &cfg, 12, DEFAULT_SEED).expect("known preset");
        let shards = sc.shards.clone().expect("heavy-tail carries a shard map");
        let pcfg = PlacementConfig::default();
        for (mode, packed) in [("packed", true), ("dedicated", false)] {
            let stats = b.run(&format!("placement/heavy-tail/{mode}"), || {
                let mut sim = if packed {
                    PlacementSim::packed(&cfg, sc.specs.clone(), 1.0e6, 3, pcfg)
                } else {
                    PlacementSim::dedicated(&cfg, sc.specs.clone(), 1.0e6, 3, pcfg)
                };
                sim.set_shard_model(shards.clone());
                let res = sim.run(40);
                (res.total_migrations(), res.total_moved_gb())
            });
            b.report_metric(
                &format!("placement/heavy-tail/{mode}"),
                stats.mean.as_secs_f64() * 1e3,
                "ms/run",
            );
        }
    }
}
