//! Bench T1 — regenerates **Table I** (paper §VI.A) and times the
//! end-to-end Phase-1 simulation per policy.
//!
//! ```text
//! cargo bench --bench table1
//! ```

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::report;
use diagonal_scale::simulator::{PolicyKind, Simulator};
use diagonal_scale::workload::TraceBuilder;

fn main() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let b = Bench::default();

    group("Table I — policy summary over the 50-step paper trace");
    let runs = sim.run_paper_set(&trace);
    let rows: Vec<_> = runs.iter().map(|r| (r.policy.clone(), r.summary)).collect();
    println!("{}", report::table1(&rows));

    group("Table I — end-to-end simulation wall time per policy");
    for kind in [
        PolicyKind::Diagonal,
        PolicyKind::HorizontalOnly,
        PolicyKind::VerticalOnly,
        PolicyKind::Threshold,
        PolicyKind::Oracle,
        PolicyKind::Lookahead(3),
    ] {
        let label = format!("phase1_sim_50_steps/{}", kind.label());
        let stats = b.run(&label, || sim.run(kind, &trace).summary.violations);
        b.report_metric(
            &format!("{label} (steps/s)"),
            50.0 * stats.per_sec(),
            "steps/s",
        );
    }
}
