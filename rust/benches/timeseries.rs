//! Benches F5–F8 — regenerate the dynamic-experiment figures:
//!   fig 5  policy trajectories in the plane
//!   fig 6  latency over time    fig 7  cost over time
//!   fig 8  objective over time
//! and time the per-figure pipeline (simulate 3 policies + serialize).
//!
//! ```text
//! cargo bench --bench timeseries
//! ```

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::report::{self, Metric};
use diagonal_scale::simulator::Simulator;
use diagonal_scale::surfaces::SurfaceModel;
use diagonal_scale::workload::TraceBuilder;

fn main() {
    let cfg = ModelConfig::default_paper();
    let sim = Simulator::new(&cfg);
    let trace = TraceBuilder::paper(&cfg);
    let model = SurfaceModel::from_config(&cfg);
    let b = Bench::default();

    let runs = sim.run_paper_set(&trace);
    std::fs::create_dir_all("out").ok();

    group("fig5 — policy trajectories in the Scaling Plane");
    let csv = report::trajectories_csv(&runs, &model);
    std::fs::write("out/fig5_trajectories.csv", &csv).unwrap();
    // terminal summary: distinct configs visited per policy
    for r in &runs {
        let mut seen: Vec<_> = r.records.iter().map(|x| x.config).collect();
        seen.dedup();
        let path: Vec<String> = seen
            .iter()
            .map(|c| format!("({},{})", model.plane().h_value(c), model.plane().tier(c).name))
            .collect();
        println!("  {:<18} {}", r.policy, path.join(" -> "));
    }
    b.run("fig5_trajectories_pipeline", || {
        let runs = sim.run_paper_set(&trace);
        report::trajectories_csv(&runs, &model).len()
    });

    for (fig, metric, file) in [
        ("fig6", Metric::Latency, "out/fig6_latency_over_time.csv"),
        ("fig7", Metric::Cost, "out/fig7_cost_over_time.csv"),
        ("fig8", Metric::Objective, "out/fig8_objective_over_time.csv"),
    ] {
        group(&format!("{fig} — {} over time by policy", metric.name()));
        let csv = report::timeseries_csv(&runs, metric);
        std::fs::write(file, &csv).unwrap();
        // phase means per policy, the figure's visual story
        println!(
            "  {:<18} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "policy", "low-1", "med-1", "high", "med-2", "low-2"
        );
        for r in &runs {
            let phase = |range: std::ops::Range<usize>| {
                let n = range.len() as f64;
                r.records[range]
                    .iter()
                    .map(|x| match metric {
                        Metric::Latency => x.latency as f64,
                        Metric::Cost => x.cost as f64,
                        Metric::Objective => x.objective as f64,
                        Metric::Throughput => x.throughput as f64,
                    })
                    .sum::<f64>()
                    / n
            };
            println!(
                "  {:<18} {:>8.2} {:>8.2} {:>8.2} {:>8.2} {:>8.2}",
                r.policy,
                phase(0..10),
                phase(10..20),
                phase(20..30),
                phase(30..40),
                phase(40..50)
            );
        }
        b.run(&format!("{fig}_timeseries_pipeline"), || {
            let runs = sim.run_paper_set(&trace);
            report::timeseries_csv(&runs, metric).len()
        });
    }
}
