//! Ablation benches (DESIGN.md A1–A5): the design choices behind
//! Algorithm 1, each isolated over the paper trace.
//!
//!   A1  SLA feasibility filter on/off       (paper §VI.F)
//!   A2  rebalance-penalty weight sweep      (paper §IV.D)
//!   A3  neighbor set: axis-only vs diagonal (paper §VI.F)
//!   A4  lookahead depth vs spike traces     (paper §VIII)
//!   A5  queueing-aware planner              (paper §VIII)
//!
//! ```text
//! cargo bench --bench ablations
//! ```

use diagonal_scale::benchkit::group;
use diagonal_scale::config::ModelConfig;
use diagonal_scale::simulator::{PolicyKind, RunResult, Simulator};
use diagonal_scale::workload::TraceBuilder;

fn row(label: &str, r: &RunResult) {
    println!(
        "  {:<34} violations={:<3} lat={:>7.2} cost={:>6.3} obj={:>8.2} fallbacks={}",
        label,
        r.summary.violations,
        r.summary.avg_latency,
        r.summary.avg_cost,
        r.summary.avg_objective,
        r.fallbacks
    );
}

fn main() {
    let cfg = ModelConfig::default_paper();
    let trace = TraceBuilder::paper(&cfg);

    group("A1 — SLA feasibility filter (paper VI.F: 'the critical fix')");
    let with = Simulator::new(&cfg).run(PolicyKind::Diagonal, &trace);
    row("filter ON (Algorithm 1)", &with);
    // filter OFF: accept any latency and any throughput shortfall — the
    // unconstrained optimizer the paper warns about
    let mut free = cfg.clone();
    free.sla.l_max = f32::MAX;
    free.sla.b_sla = 0.0;
    // keep the *audit* at paper levels: re-run under the free planner but
    // count violations against the real SLA
    let free_run = Simulator::new(&free).run(PolicyKind::Diagonal, &trace);
    let audit = diagonal_scale::sla::SlaSpec::new(cfg.sla.l_max, cfg.sla.b_sla);
    let mut counter = diagonal_scale::sla::ViolationCounter::default();
    for rec in &free_run.records {
        counter.record(audit.audit(rec.latency_raw, rec.throughput, rec.lambda_req));
    }
    println!(
        "  {:<34} violations={:<3} lat={:>7.2} cost={:>6.3} obj={:>8.2}  (audited at the real SLA)",
        "filter OFF (unconstrained min F)",
        counter.violated_steps,
        free_run.summary.avg_latency,
        free_run.summary.avg_cost,
        free_run.summary.avg_objective
    );
    println!(
        "  -> without the filter the optimizer parks on cheap configs and violates {}x more\n",
        (counter.violated_steps.max(1)) / with.summary.violations.max(1)
    );

    group("A2 — rebalance penalty weights (paper IV.D)");
    for (rh, rv) in [(0.0, 0.0), (1.0, 0.5), (2.0, 1.0), (8.0, 4.0), (50.0, 25.0)] {
        let sim = Simulator::new(&cfg).with_rebalance(rh, rv);
        let r = sim.run(PolicyKind::Diagonal, &trace);
        let moves = r
            .records
            .windows(2)
            .filter(|w| w[0].config != w[1].config)
            .count();
        println!(
            "  reb_h={rh:<5} reb_v={rv:<5} violations={:<3} moves={:<3} cost={:>6.3} obj={:>8.2}",
            r.summary.violations, moves, r.summary.avg_cost, r.summary.avg_objective
        );
    }
    println!("  -> the paper's (2, 1) sits on the plateau: dampens thrash without losing reactivity\n");

    group("A3 — neighbor set: diagonal moves as first-class candidates (paper VI.F)");
    let sim = Simulator::new(&cfg);
    row("full neighborhood (DiagonalScale)", &sim.run(PolicyKind::Diagonal, &trace));
    row("horizontal axis only", &sim.run(PolicyKind::HorizontalOnly, &trace));
    row("vertical axis only", &sim.run(PolicyKind::VerticalOnly, &trace));
    row("oracle (whole plane, no locality)", &sim.run(PolicyKind::Oracle, &trace));
    println!();

    group("A4 — lookahead depth on a sudden spike (paper VIII ext. 3)");
    let b = TraceBuilder::from_config(&cfg);
    let spike = b.spike(40.0, 160.0, 15, 10, 40);
    for depth in [1usize, 2, 3] {
        let kind = if depth == 1 { PolicyKind::Diagonal } else { PolicyKind::Lookahead(depth) };
        let r = sim.run(kind, &spike);
        row(&format!("depth {depth}"), &r);
    }
    println!();

    group("A5 — queueing-aware planner (paper VIII ext. 1)");
    let raw = Simulator::new(&cfg).run(PolicyKind::Diagonal, &trace);
    let over = |r: &RunResult, bound: f32| {
        r.records.iter().filter(|x| x.latency > bound).count()
    };
    println!(
        "  {:<34} measured-latency excursions over l_max: {}",
        "raw Phase-1 planner",
        over(&raw, cfg.sla.l_max)
    );
    let mut qcfg = cfg.clone();
    qcfg.sla.l_max = 10.0;
    let q = Simulator::new(&qcfg)
        .with_plan_queue(true)
        .run(PolicyKind::Diagonal, &trace);
    println!(
        "  {:<34} measured-latency excursions over l_max: {}",
        "queueing-aware planner (l_max=10)",
        over(&q, qcfg.sla.l_max)
    );
    println!("  -> with the 1/(1-u) term the bound holds in *measured* latency terms");
}
