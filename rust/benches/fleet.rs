//! Bench F — fleet decision-loop throughput: full tick wall time
//! (serve + propose + arbitrate + actuate for every tenant) as the
//! tenant count sweeps 1 → 64, analytical first and then with every
//! tenant backed by the event-driven DES engine (full queueing physics
//! per tick).
//!
//! ```text
//! cargo bench --bench fleet
//! ```
//!
//! The surface model is shared across tenants and per-decision surface
//! lookups are cache-table reads, so the marginal tenant is cheap: the
//! fitted scaling exponent of tick cost vs tenant count comes out below
//! 1.0 (sub-linear) on the sweep endpoints.

// Benches measure wall time by design; decision code is covered by
// simlint's d1-no-wall-clock + clippy's disallowed_methods instead.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use diagonal_scale::benchkit::{group, Bench};
use diagonal_scale::cluster::{ClusterParams, SubstrateKind};
use diagonal_scale::config::ModelConfig;
use diagonal_scale::fleet::{
    BudgetArbiter, ClassEnvelopes, FleetSimulator, ForecastKind, PriorityClass, TenantSpec,
};
use diagonal_scale::serverless::{mostly_idle_specs, sparse_activity_specs, ServerlessParams};
use diagonal_scale::workload::TraceBuilder;

fn specs(cfg: &ModelConfig, n: usize) -> Vec<TenantSpec> {
    let base = TraceBuilder::paper(cfg);
    (0..n)
        .map(|i| {
            let class = match i % 3 {
                0 => PriorityClass::Gold,
                1 => PriorityClass::Silver,
                _ => PriorityClass::Bronze,
            };
            TenantSpec::from_config(
                cfg,
                format!("t{i:02}"),
                class,
                base.shifted(i * base.len() / n),
            )
        })
        .collect()
}

fn build_fleet(cfg: &ModelConfig, n: usize) -> FleetSimulator {
    // budget scaled per tenant so contention (and the arbiter's full
    // knapsack path) is exercised at every fleet size
    let mut fleet = FleetSimulator::new(cfg, specs(cfg, n), 2.2 * n as f32, 3);
    fleet.set_recording(false); // bounded memory over millions of ticks
    fleet
}

fn main() {
    let cfg = ModelConfig::default_paper();
    let b = Bench::default();

    group("fleet decision loop — full tick wall time vs tenant count");
    let mut points: Vec<(usize, f64)> = Vec::new();
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut fleet = build_fleet(&cfg, n);
        let stats = b.run(&format!("fleet_tick/{n:>2}_tenants"), || {
            fleet.tick().admitted_moves
        });
        b.report_metric(
            &format!("fleet_tick/{n:>2}_tenants per-tenant"),
            stats.mean.as_secs_f64() * 1e9 / n as f64,
            "ns/tenant/tick",
        );
        points.push((n, stats.mean.as_secs_f64()));
    }

    group("scaling fit");
    let (n0, t0) = points[0];
    let (n1, t1) = *points.last().unwrap();
    let alpha = (t1 / t0).ln() / ((n1 as f64) / (n0 as f64)).ln();
    b.report_metric("tick-cost scaling exponent (1.0 = linear)", alpha, "alpha");
    if alpha < 1.0 {
        println!(
            "decision-loop time scales SUB-LINEARLY in tenant count \
             (alpha = {alpha:.2}: shared surface model + amortized per-tick overhead)"
        );
    } else {
        println!("decision-loop time scaled super-linearly (alpha = {alpha:.2}) — investigate");
    }

    group("planning admission overhead — flat denial vs full planning (16 tenants)");
    {
        let n = 16;
        let budget = 2.2 * n as f32;
        let mut flat = FleetSimulator::with_arbiter(
            &cfg,
            specs(&cfg, n),
            BudgetArbiter::flat(budget, 3),
        );
        flat.set_recording(false);
        let flat_stats = b.run("fleet_tick/flat_denial", || flat.tick().admitted_moves);
        let arb =
            BudgetArbiter::new(budget, 3).with_envelopes(ClassEnvelopes::default_split());
        let mut plan = FleetSimulator::with_arbiter(&cfg, specs(&cfg, n), arb);
        plan.enable_forecasts(ForecastKind::Seasonal, 3);
        plan.set_recording(false);
        let plan_stats =
            b.run("fleet_tick/planning+envelopes+forecast", || plan.tick().admitted_moves);
        b.report_metric(
            "planning/flat tick-time ratio",
            plan_stats.mean.as_secs_f64() / flat_stats.mean.as_secs_f64().max(1e-12),
            "x",
        );
    }

    group("fleet decision loop — DES(event)-backed tenants, full queueing physics");
    let bq = Bench::quick();
    for n in [8usize, 64] {
        let mut fleet = build_fleet(&cfg, n);
        fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
        let stats = bq.run(&format!("fleet_tick_des/{n:>2}_tenants"), || {
            fleet.tick().admitted_moves
        });
        bq.report_metric(
            &format!("fleet_tick_des/{n:>2}_tenants per-tenant"),
            stats.mean.as_secs_f64() * 1e6 / n as f64,
            "us/tenant/tick",
        );
    }

    // acceptance sweep: 64 event-backed tenants through one full paper
    // trace (every tenant serving, proposing, and being arbitrated)
    let mut fleet = build_fleet(&cfg, 64);
    fleet.attach_substrates(&cfg, ClusterParams::default(), 42, SubstrateKind::Des);
    let steps = TraceBuilder::paper(&cfg).len();
    let t = Instant::now();
    for _ in 0..steps {
        fleet.tick();
    }
    let secs = t.elapsed().as_secs_f64();
    b.report_metric("64 DES tenants, full 50-tick sweep", secs, "s total");
    b.report_metric("64 DES tenants, full 50-tick sweep", steps as f64 / secs, "ticks/s");

    group("serverless tier — mostly-idle fleet (64 tenants, 75% idle), scale-to-zero vs always-on");
    {
        let n = 64;
        let mut on = FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, 0.75), 1.0e6, 3);
        on.set_recording(false);
        let on_stats = bq.run("fleet_tick_idle/always_on", || on.tick().admitted_moves);
        let mut sv = FleetSimulator::new(&cfg, mostly_idle_specs(&cfg, n, 0.75), 1.0e6, 3);
        sv.enable_serverless(ServerlessParams::default());
        sv.set_recording(false);
        let sv_stats = bq.run("fleet_tick_idle/serverless", || sv.tick().admitted_moves);
        bq.report_metric(
            "serverless/always-on tick-time ratio",
            sv_stats.mean.as_secs_f64() / on_stats.mean.as_secs_f64().max(1e-12),
            "x",
        );
        // after the warm benchmark sweeps both fleets sit deep in the
        // trace cycle — compare one more tick's spend directly
        let (t_on, t_sv) = (on.tick(), sv.tick());
        bq.report_metric("steady-state spend, always-on", t_on.spend as f64, "/h");
        bq.report_metric("steady-state spend, serverless", t_sv.spend as f64, "/h");
        bq.report_metric("suspended tenants at steady state", t_sv.suspended as f64, "tenants");
    }

    group("dirty-queue scale sweep — sparse-activity serverless fleets to 10240 tenants");
    // Fixed activity: 16 trace-driven + 8 bursty tenants regardless of
    // fleet size; everyone else parks after the initial idle window.
    // With the dirty queue on, per-tick planning cost must track the
    // active set, not N — the tier-2 test in tests/fleet_scale.rs pins
    // the fresh-proposal proxy; this sweep reports the wall-clock view.
    // DES-backed active cohort: the idle sea stays analytical so the
    // sweep measures control-plane cost, not 10k idle queue models.
    for n in [64usize, 512, 2048, 10240] {
        let specs = sparse_activity_specs(&cfg, n, 16.min(n / 4), 8.min(n / 8));
        let mut fleet = FleetSimulator::new(&cfg, specs, 1.0e6, 3);
        fleet.enable_serverless(ServerlessParams::default());
        fleet.attach_mixed_substrates(&cfg, ClusterParams::default(), 42, |id| {
            if id < 16 {
                SubstrateKind::Des
            } else {
                SubstrateKind::Analytical
            }
        });
        // bounded observation instead of none: the streaming recorder
        // keeps summaries + sketches + 32 exemplars per tenant in O(1)
        // memory, so the sweep now measures the honest control plane
        // (observation included) rather than a blind one
        fleet.enable_streaming_metrics(32);
        // opt in to wall-clock planning latency (the default planning
        // clock is deterministically zero)
        fleet.use_wall_clock();
        // park the idle sea before measuring (suspension takes
        // idle_ticks + a drain tick to complete)
        let mut warm_fresh = 0usize;
        for _ in 0..16 {
            warm_fresh += fleet.tick().fresh_proposals;
        }
        let mut fresh = 0usize;
        let mut micros = 0u64;
        let mut ticks = 0usize;
        let stats = bq.run(&format!("fleet_tick_sparse/{n:>5}_tenants"), || {
            let t = fleet.tick();
            fresh += t.fresh_proposals;
            micros += t.planning_micros;
            ticks += 1;
            t.admitted_moves
        });
        bq.report_metric(
            &format!("fleet_tick_sparse/{n:>5}_tenants warmup fresh"),
            warm_fresh as f64 / 16.0,
            "proposals/tick",
        );
        bq.report_metric(
            &format!("fleet_tick_sparse/{n:>5}_tenants steady fresh"),
            fresh as f64 / ticks.max(1) as f64,
            "proposals/tick",
        );
        bq.report_metric(
            &format!("fleet_tick_sparse/{n:>5}_tenants planning"),
            micros as f64 / ticks.max(1) as f64,
            "us/tick",
        );
        bq.report_metric(
            &format!("fleet_tick_sparse/{n:>5}_tenants tick"),
            stats.mean.as_secs_f64() * 1e6,
            "us/tick",
        );
    }
}
